//! Losses. Softmax cross-entropy is the paper's training loss; its output
//! delta (softmax(z) - y) is exactly the Δ_L of eq. (2) — UNSCALED here, the
//! coordinator applies 1/(S*N) so one code path serves any site count.

use crate::tensor::Matrix;

/// Softmax cross-entropy: returns (mean loss over rows, UNSCALED output
/// delta p - y). `y` is one-hot (N, C).
pub fn softmax_xent(logits: &Matrix, y: &Matrix) -> (f32, Matrix) {
    let mut delta = Matrix::zeros(logits.rows(), logits.cols());
    let loss = softmax_xent_into(logits, y, &mut delta);
    (loss, delta)
}

/// Allocation-free softmax cross-entropy: writes the UNSCALED delta p - y
/// into `delta` (a workspace buffer on the hot path) and returns the mean
/// loss over rows.
pub fn softmax_xent_into(logits: &Matrix, y: &Matrix, delta: &mut Matrix) -> f32 {
    assert_eq!(logits.shape(), y.shape());
    assert_eq!(delta.shape(), logits.shape());
    let n = logits.rows();
    let mut loss = 0.0f64;
    for i in 0..n {
        let zrow = logits.row(i);
        let yrow = y.row(i);
        let drow = delta.row_mut(i);
        let mx = zrow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for (dv, &zv) in drow.iter_mut().zip(zrow) {
            let e = (zv - mx).exp();
            *dv = e;
            sum += e;
        }
        let lse = sum.ln() + mx;
        let inv = 1.0 / sum;
        for (j, (dv, &yv)) in drow.iter_mut().zip(yrow).enumerate() {
            *dv = *dv * inv - yv;
            if yv != 0.0 {
                loss -= (yv * (zrow[j] - lse)) as f64;
            }
        }
    }
    (loss / n as f64) as f32
}

/// Mean-squared error: returns (mean over entries, UNSCALED delta 2(p-y)/C).
pub fn mse(pred: &Matrix, y: &Matrix) -> (f32, Matrix) {
    assert_eq!(pred.shape(), y.shape());
    let diff = pred.sub(y);
    let n = pred.numel() as f32;
    let loss = diff.data().iter().map(|&d| d * d).sum::<f32>() / n;
    let delta = diff.scale(2.0 * pred.rows() as f32 / n); // per-row-mean scale
    (loss, delta)
}

/// One-hot encode labels into (n, classes).
pub fn one_hot(labels: &[usize], classes: usize) -> Matrix {
    let mut y = Matrix::zeros(labels.len(), classes);
    for (i, &l) in labels.iter().enumerate() {
        assert!(l < classes, "label {l} out of range {classes}");
        y[(i, l)] = 1.0;
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::activations::softmax_rows;
    use crate::tensor::Rng;

    #[test]
    fn xent_uniform_is_log_c() {
        let logits = Matrix::zeros(4, 10);
        let y = one_hot(&[0, 3, 5, 9], 10);
        let (loss, _) = softmax_xent(&logits, &y);
        assert!((loss - (10.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn xent_delta_is_p_minus_y() {
        let mut rng = Rng::new(1);
        let logits = Matrix::randn(6, 5, 1.0, &mut rng);
        let y = one_hot(&[0, 1, 2, 3, 4, 0], 5);
        let (_, delta) = softmax_xent(&logits, &y);
        let p = softmax_rows(&logits);
        assert!(delta.max_abs_diff(&p.sub(&y)) < 1e-6);
        // Rows of p - y sum to zero.
        for i in 0..6 {
            let s: f32 = delta.row(i).iter().sum();
            assert!(s.abs() < 1e-5);
        }
    }

    #[test]
    fn xent_delta_is_loss_gradient() {
        // Finite-difference check: d(mean loss)/dz == delta / N.
        let mut rng = Rng::new(2);
        let logits = Matrix::randn(3, 4, 0.5, &mut rng);
        let y = one_hot(&[1, 2, 0], 4);
        let (_, delta) = softmax_xent(&logits, &y);
        let eps = 1e-3f32;
        for i in 0..3 {
            for j in 0..4 {
                let mut zp = logits.clone();
                zp[(i, j)] += eps;
                let mut zm = logits.clone();
                zm[(i, j)] -= eps;
                let fd = (softmax_xent(&zp, &y).0 - softmax_xent(&zm, &y).0) / (2.0 * eps);
                let an = delta[(i, j)] / 3.0;
                assert!((fd - an).abs() < 1e-3, "({i},{j}): fd={fd} an={an}");
            }
        }
    }

    #[test]
    fn mse_zero_at_target() {
        let y = Matrix::filled(2, 3, 0.7);
        let (loss, delta) = mse(&y, &y);
        assert_eq!(loss, 0.0);
        assert_eq!(delta.max_abs(), 0.0);
    }

    #[test]
    fn one_hot_rows() {
        let y = one_hot(&[2, 0], 3);
        assert_eq!(y.data(), &[0.0, 0.0, 1.0, 1.0, 0.0, 0.0]);
    }
}

//! Feed-forward softmax-CE classifier — the paper's MNIST architecture
//! (784 -> 1024 -> 1024 -> 10, ReLU), with the reverse-AD backward pass
//! exposed as per-layer (A, Δ) statistics.
//!
//! Parameter layout (flat list): [W_1, b_1, W_2, b_2, ..., W_L, b_L], with
//! W_i (h_{i-1}, h_i) and b_i (1, h_i). Stats entry i covers (W_{i+1},
//! b_{i+1}) with A = A_i, Δ = Δ_{i+1} — exactly Algorithm 1's payloads.

use crate::nn::activations::{softmax_rows, Activation};
use crate::nn::init::he_uniform;
use crate::nn::loss::softmax_xent_into;
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::{LocalStats, StatsEntry};
use crate::tensor::{matmul, matmul_into, matmul_nt, matmul_nt_into, Matrix, Rng, Workspace};

/// Feed-forward network with softmax cross-entropy output.
#[derive(Clone)]
pub struct Mlp {
    /// Layer dims: [input, hidden..., classes].
    pub dims: Vec<usize>,
    /// Hidden activations (len = dims.len() - 2); output is softmax-CE.
    pub acts: Vec<Activation>,
    ws: Vec<Matrix>,
    bs: Vec<Matrix>,
}

impl Mlp {
    /// He-uniform init; deterministic in `rng` (sites share the seed).
    pub fn new(dims: &[usize], acts: &[Activation], rng: &mut Rng) -> Self {
        assert!(dims.len() >= 2);
        assert_eq!(acts.len(), dims.len() - 2, "one activation per hidden layer");
        let mut ws = Vec::new();
        let mut bs = Vec::new();
        for (&h_in, &h_out) in dims.iter().zip(&dims[1..]) {
            ws.push(he_uniform(h_in, h_out, rng));
            bs.push(Matrix::zeros(1, h_out));
        }
        Mlp { dims: dims.to_vec(), acts: acts.to_vec(), ws, bs }
    }

    /// The paper's MNIST network: 784-1024-1024-10, ReLU hidden layers.
    pub fn paper_mnist(rng: &mut Rng) -> Self {
        Mlp::new(&[784, 1024, 1024, 10], &[Activation::Relu, Activation::Relu], rng)
    }

    /// Number of dense layers.
    pub fn n_layers(&self) -> usize {
        self.ws.len()
    }

    /// Weight matrix of layer `i`.
    pub fn weight(&self, i: usize) -> &Matrix {
        &self.ws[i]
    }

    /// Forward pass returning all activations [A_0 = x, A_1, ..., A_L].
    /// A_L holds *logits* (softmax applied only inside the loss / predict).
    pub fn forward(&self, x: &Matrix) -> Vec<Matrix> {
        let mut acts = Vec::with_capacity(self.n_layers() + 1);
        acts.push(x.clone());
        for i in 0..self.n_layers() {
            let mut z = matmul(acts.last().unwrap(), &self.ws[i]);
            add_bias(&mut z, &self.bs[i]);
            if i + 1 < self.n_layers() {
                self.acts[i].apply(&mut z);
            }
            acts.push(z);
        }
        acts
    }

    /// Logits for a dense batch.
    pub fn logits(&self, x: &Matrix) -> Matrix {
        self.forward(x).pop().unwrap()
    }

    /// Backward delta recurrence from an output delta and activation list —
    /// shared by local_stats and edad_recompute (they differ only in whose
    /// activations are fed in: local or aggregated).
    fn backward_deltas(&self, acts: &[Matrix], delta_out: Matrix) -> Vec<Matrix> {
        let l = self.n_layers();
        let mut deltas = vec![Matrix::zeros(0, 0); l];
        deltas[l - 1] = delta_out;
        for i in (0..l - 1).rev() {
            // Δ_i = (Δ_{i+1} W_{i+1}ᵀ) ⊙ φ'_i(A_{i+1-th activation}) (eq. 3/5)
            let mut d = matmul_nt(&deltas[i + 1], &self.ws[i + 1]);
            self.acts[i].mask_delta_inplace(&mut d, &acts[i + 1]);
            deltas[i] = d;
        }
        deltas
    }
}

/// z += bias (broadcast row). Allocation-free: the hot path calls this
/// every layer of every step.
pub fn add_bias(z: &mut Matrix, b: &Matrix) {
    debug_assert_eq!(b.rows(), 1);
    debug_assert_eq!(z.cols(), b.cols());
    let cols = z.cols();
    let brow = b.data();
    for row in z.data_mut().chunks_exact_mut(cols) {
        for (v, &bv) in row.iter_mut().zip(brow) {
            *v += bv;
        }
    }
}

impl DistModel for Mlp {
    fn param_shapes(&self) -> Vec<(usize, usize)> {
        let mut shapes = Vec::new();
        for (w, b) in self.ws.iter().zip(&self.bs) {
            shapes.push(w.shape());
            shapes.push(b.shape());
        }
        shapes
    }

    fn params(&self) -> Vec<&Matrix> {
        self.ws.iter().zip(&self.bs).flat_map(|(w, b)| [w, b]).collect()
    }

    fn params_mut(&mut self) -> Vec<&mut Matrix> {
        self.ws
            .iter_mut()
            .zip(self.bs.iter_mut())
            .flat_map(|(w, b)| [w, b])
            .collect()
    }

    /// The allocation-free hot path: every activation, delta and the loss
    /// delta live in `arena` buffers; `out`'s previous stacks are recycled
    /// first, so a steady-state (reused arena + reused out) step performs
    /// zero heap allocations — asserted by tests/alloc_free.rs.
    fn local_stats_into(&self, batch: &Batch, arena: &mut Workspace, out: &mut LocalStats) {
        let (x, y) = match batch {
            Batch::Dense { x, y } => (x, y),
            _ => panic!("Mlp consumes dense batches"),
        };
        out.recycle_into(arena);
        let l = self.n_layers();
        // Forward: acts[0] = x, acts[i+1] = phi_i(acts[i] W_i + b_i).
        let mut acts = arena.take_list();
        acts.push(arena.copy_in(x));
        for i in 0..l {
            let mut z = arena.take(x.rows(), self.ws[i].cols());
            matmul_into(&acts[i], &self.ws[i], &mut z);
            add_bias(&mut z, &self.bs[i]);
            if i + 1 < l {
                self.acts[i].apply(&mut z);
            }
            acts.push(z);
        }
        // Loss + output delta (UNSCALED p - y).
        let logits = acts.last().unwrap();
        let mut delta_out = arena.take(logits.rows(), logits.cols());
        out.loss = softmax_xent_into(logits, y, &mut delta_out);
        // Backward recurrence, built deepest-last then reversed in place:
        // Δ_i = (Δ_{i+1} W_{i+1}ᵀ) ⊙ φ'_i(A_{i+1}) (eq. 3/5).
        let mut deltas = arena.take_list();
        deltas.push(delta_out);
        for i in (0..l.saturating_sub(1)).rev() {
            let top = deltas.last().unwrap();
            let mut d = arena.take(top.rows(), self.ws[i + 1].rows());
            matmul_nt_into(top, &self.ws[i + 1], &mut d);
            self.acts[i].mask_delta_inplace(&mut d, &acts[i + 1]);
            deltas.push(d);
        }
        deltas.reverse();
        // Hand the stacks to the caller; recycle what stays behind.
        {
            let mut a_it = acts.drain(..);
            let mut d_it = deltas.drain(..);
            for i in 0..l {
                out.entries.push(StatsEntry {
                    w_idx: 2 * i,
                    b_idx: Some(2 * i + 1),
                    a: a_it.next().expect("activation stack"),
                    d: d_it.next().expect("delta stack"),
                });
            }
            if let Some(logits) = a_it.next() {
                // A_L (logits) never ships; Δ_L carries the output info.
                arena.recycle(logits);
            }
        }
        arena.recycle_list(acts);
        arena.recycle_list(deltas);
    }

    fn predict(&self, batch: &Batch) -> Matrix {
        let x = match batch {
            Batch::Dense { x, .. } => x,
            _ => panic!("Mlp consumes dense batches"),
        };
        softmax_rows(&self.logits(x))
    }

    fn edad_recompute(
        &self,
        a_hats: &[Matrix],
        _aux: &[Matrix],
        delta_out: &Matrix,
        _site_rows: &[usize],
    ) -> Option<Vec<StatsEntry>> {
        // a_hats[i] = aggregated A_i for i = 0..L-1; A_L (logits) is never
        // needed because Δ_L itself is communicated (Algorithm 2 line 16).
        let l = self.n_layers();
        assert_eq!(a_hats.len(), l);
        let mut acts: Vec<Matrix> = a_hats.to_vec();
        acts.push(Matrix::zeros(0, 0)); // placeholder for logits (unused)
        let deltas = self.backward_deltas(&acts, delta_out.clone());
        Some(
            (0..l)
                .map(|i| StatsEntry {
                    w_idx: 2 * i,
                    b_idx: Some(2 * i + 1),
                    a: a_hats[i].clone(),
                    d: deltas[i].clone(),
                })
                .collect(),
        )
    }

    fn local_stats_entry_count(&self) -> usize {
        self.n_layers()
    }

    fn entry_names(&self) -> Vec<String> {
        (0..self.n_layers())
            .map(|i| {
                if i + 1 == self.n_layers() {
                    format!("output ({}x{})", self.dims[i], self.dims[i + 1])
                } else {
                    format!("fc{} ({}x{})", i + 1, self.dims[i], self.dims[i + 1])
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::loss::one_hot;

    fn tiny(rng: &mut Rng) -> Mlp {
        Mlp::new(&[6, 8, 5, 3], &[Activation::Relu, Activation::Tanh], rng)
    }

    fn batch(rng: &mut Rng, n: usize, d: usize, c: usize) -> Batch {
        let x = Matrix::randn(n, d, 1.0, rng);
        let labels: Vec<usize> = (0..n).map(|i| i % c).collect();
        Batch::Dense { x, y: one_hot(&labels, c) }
    }

    /// The decisive correctness test: gradients assembled from the AD
    /// statistics must match central finite differences of the loss.
    #[test]
    fn stats_grads_match_finite_difference() {
        let mut rng = Rng::new(7);
        let mlp = tiny(&mut rng);
        let b = batch(&mut rng, 5, 6, 3);
        let stats = mlp.local_stats(&b);
        let shapes = mlp.param_shapes();
        let n = b.len() as f32;
        let grads = stats.assemble_grads(&shapes, 1.0 / n, 1.0);

        let loss_of = |m: &Mlp| {
            let s = m.local_stats(&b);
            s.loss
        };
        let eps = 5e-3f32;
        for (pi, g) in grads.iter().enumerate() {
            // Spot-check a handful of coordinates per parameter.
            let (rows, cols) = g.shape();
            for &(i, j) in &[(0usize, 0usize), (rows / 2, cols / 2), (rows - 1, cols - 1)] {
                let mut mp = mlp.clone();
                mp.params_mut()[pi][(i, j)] += eps;
                let mut mm = mlp.clone();
                mm.params_mut()[pi][(i, j)] -= eps;
                let fd = (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps);
                let an = g[(i, j)];
                assert!(
                    (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                    "param {pi} ({i},{j}): fd={fd} an={an}"
                );
            }
        }
    }

    /// edAD recompute from aggregated activations must reproduce the
    /// concatenation of local deltas exactly (Algorithm 2's claim).
    #[test]
    fn edad_recompute_equals_concat() {
        let mut rng = Rng::new(11);
        let mlp = tiny(&mut rng);
        let b1 = batch(&mut rng, 4, 6, 3);
        let b2 = batch(&mut rng, 4, 6, 3);
        let s1 = mlp.local_stats(&b1);
        let s2 = mlp.local_stats(&b2);
        let a_hats: Vec<Matrix> = (0..s1.entries.len())
            .map(|i| Matrix::vertcat(&[&s1.entries[i].a, &s2.entries[i].a]))
            .collect();
        let d_l = Matrix::vertcat(&[&s1.entries.last().unwrap().d, &s2.entries.last().unwrap().d]);
        let re = mlp.edad_recompute(&a_hats, &[], &d_l, &[4, 4]).unwrap();
        for i in 0..re.len() {
            let d_cat = Matrix::vertcat(&[&s1.entries[i].d, &s2.entries[i].d]);
            let diff = re[i].d.max_abs_diff(&d_cat);
            assert!(diff < 1e-5, "layer {i} delta mismatch {diff}");
        }
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let mlp = Mlp::paper_mnist(&mut rng);
        let x = Matrix::randn(3, 784, 1.0, &mut rng);
        let acts = mlp.forward(&x);
        assert_eq!(acts.len(), 4);
        assert_eq!(acts[1].shape(), (3, 1024));
        assert_eq!(acts[3].shape(), (3, 10));
    }

    #[test]
    fn predict_rows_are_distributions() {
        let mut rng = Rng::new(2);
        let mlp = tiny(&mut rng);
        let b = batch(&mut rng, 4, 6, 3);
        let p = mlp.predict(&b);
        for i in 0..4 {
            let s: f32 = p.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn param_roundtrip() {
        let mut rng = Rng::new(3);
        let mut mlp = tiny(&mut rng);
        let shapes = mlp.param_shapes();
        assert_eq!(shapes.len(), 6);
        let snapshot: Vec<Matrix> = mlp.params().into_iter().cloned().collect();
        mlp.params_mut()[0][(0, 0)] += 1.0;
        assert_ne!(*mlp.params()[0], snapshot[0]);
        mlp.set_params(&snapshot);
        assert_eq!(*mlp.params()[0], snapshot[0]);
    }

    #[test]
    fn training_reduces_loss() {
        use crate::nn::optimizer::Adam;
        let mut rng = Rng::new(5);
        let mut mlp = tiny(&mut rng);
        let b = batch(&mut rng, 16, 6, 3);
        let shapes = mlp.param_shapes();
        let mut opt = Adam::new(1e-2, &shapes);
        let first = mlp.local_stats(&b).loss;
        for _ in 0..60 {
            let stats = mlp.local_stats(&b);
            let grads = stats.assemble_grads(&shapes, 1.0 / 16.0, 1.0);
            let mut params: Vec<Matrix> = mlp.params().into_iter().cloned().collect();
            opt.step(&mut params, &grads);
            mlp.set_params(&params);
        }
        let last = mlp.local_stats(&b).loss;
        assert!(last < first * 0.5, "loss did not drop: {first} -> {last}");
    }
}

//! Neural-network substrate: layers, losses, optimizers, and the three
//! model families the paper evaluates (feed-forward MLP, GRU classifier)
//! plus a decoder-only transformer for the end-to-end driver — all exposing
//! reverse-AD statistics (A, Δ) per dense parameter via `DistModel`.

pub mod activations;
pub mod gru;
pub mod init;
pub mod loss;
pub mod mlp;
pub mod model;
pub mod optimizer;
pub mod stats;
pub mod transformer;

pub use activations::Activation;
pub use gru::GruClassifier;
pub use mlp::Mlp;
pub use model::{Batch, DistModel};
pub use optimizer::{Adam, Sgd};
pub use stats::{LocalStats, StatsEntry};
pub use transformer::{Transformer, TransformerConfig};

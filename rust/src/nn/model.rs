//! Common model interface consumed by the distributed algorithms.
//!
//! Every architecture (MLP, GRU classifier, decoder-only transformer)
//! exposes the same contract: produce AD statistics for a batch, accept a
//! synchronized gradient list, and score inputs for evaluation. The
//! algorithms in `crate::algos` are generic over this trait, which is what
//! makes dAD a *first-class feature* rather than something wired into one
//! model.

use crate::nn::stats::{LocalStats, StatsEntry};
use crate::tensor::{Matrix, Workspace};

/// A batch of training data, in whichever layout the model consumes.
#[derive(Clone, Debug)]
pub enum Batch {
    /// Dense features: x (N, d), y one-hot (N, C).
    Dense { x: Matrix, y: Matrix },
    /// Sequences: `xs[t]` is (N, c_in) for t = 0..T; y one-hot (N, C).
    Seq { xs: Vec<Matrix>, y: Matrix },
    /// Token streams for the LM: ids/targets are (B, T) row-major.
    Tokens { b: usize, t: usize, ids: Vec<u32>, targets: Vec<u32> },
}

impl Batch {
    /// Number of rows of the eventual output delta — the batch's weight in
    /// every cross-site reduction (loss weighting, the 1/N gradient scale,
    /// `StepMeta::rows`). For dense/sequence batches that is the example
    /// count; a token batch predicts at every position, so its delta has
    /// `b * t` rows, not `b`.
    pub fn len(&self) -> usize {
        match self {
            Batch::Dense { x, .. } => x.rows(),
            Batch::Seq { y, .. } => y.rows(),
            Batch::Tokens { b, t, .. } => b * t,
        }
    }

    /// True for a zero-example batch.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The one-hot label matrix, when the batch layout carries one.
    pub fn labels_onehot(&self) -> Option<&Matrix> {
        match self {
            Batch::Dense { y, .. } | Batch::Seq { y, .. } => Some(y),
            Batch::Tokens { .. } => None,
        }
    }
}

/// Model contract for distributed training.
pub trait DistModel {
    /// Shapes of the flat parameter list (weights, biases, everything
    /// updatable), in canonical order.
    fn param_shapes(&self) -> Vec<(usize, usize)>;
    /// The parameters, aligned with `param_shapes`.
    fn params(&self) -> Vec<&Matrix>;
    /// Mutable access to the parameters, aligned with `param_shapes`.
    fn params_mut(&mut self) -> Vec<&mut Matrix>;

    /// Forward + backward on a local batch, producing the paper's
    /// statistics. The workspace-threaded core: buffers come from `ws` and
    /// `out`'s previous contents are recycled into `ws` first, so a caller
    /// that reuses both performs zero steady-state heap allocations
    /// (asserted for the MLP by tests/alloc_free.rs).
    fn local_stats_into(&self, batch: &Batch, ws: &mut Workspace, out: &mut LocalStats);

    /// Workspace-reusing convenience wrapper around `local_stats_into`.
    fn local_stats_ws(&self, batch: &Batch, ws: &mut Workspace) -> LocalStats {
        let mut out = LocalStats::empty();
        self.local_stats_into(batch, ws, &mut out);
        out
    }

    /// Allocating convenience wrapper (one-shot callers, tests).
    fn local_stats(&self, batch: &Batch) -> LocalStats {
        self.local_stats_ws(batch, &mut Workspace::new())
    }

    /// Class scores (N, C) for evaluation (softmax probabilities).
    fn predict(&self, batch: &Batch) -> Matrix;

    /// edAD (Algorithm 2): recompute the full aggregated delta stacks from
    /// the aggregated A-stacks (`a_hats`, one per stats entry, in entry
    /// order), the aggregated aux activations and the aggregated output
    /// delta. `site_rows` gives each site's example count — needed by
    /// models whose stacks are site-major with t-major blocks inside
    /// (recurrent nets). Returns None if the architecture does not support
    /// the activation-derivative recurrence (e.g. attention).
    fn edad_recompute(
        &self,
        a_hats: &[Matrix],
        aux: &[Matrix],
        delta_out: &Matrix,
        site_rows: &[usize],
    ) -> Option<Vec<StatsEntry>>;

    /// Whether the architecture supports edAD's delta recomputation
    /// (Algorithm 2) — i.e. whether [`DistModel::edad_recompute`] can
    /// return `Some`. Coordinators use this to reject `edad` for
    /// unsupported architectures (attention mixes rows, so the transformer
    /// returns false) *before* any training step runs, instead of
    /// panicking mid-step.
    fn supports_edad(&self) -> bool {
        true
    }

    /// Human-readable per-entry layer names (for Table-2 / effective-rank
    /// reporting). Default: entry indices.
    fn entry_names(&self) -> Vec<String> {
        (0..self.local_stats_entry_count()).map(|i| format!("entry{i}")).collect()
    }

    /// Number of stats entries a batch produces (layers with dense weights).
    fn local_stats_entry_count(&self) -> usize;

    /// In-place parameter update: p -= ... is the optimizer's job; models
    /// only expose storage. Provided for convenience.
    fn set_params(&mut self, new: &[Matrix]) {
        for (p, n) in self.params_mut().into_iter().zip(new) {
            *p = n.clone();
        }
    }
}

/// Clone-able model handle: sites hold replicas; `replicate` must produce a
/// bit-identical copy (the paper's "same random seed" requirement).
pub trait Replicate: Sized {
    /// Produce a bit-identical copy.
    fn replicate(&self) -> Self;
}

impl<T: Clone> Replicate for T {
    fn replicate(&self) -> T {
        self.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `Batch::len` is the output-delta row count for every layout: a token
    /// batch contributes `b * t` rows (one prediction per position), not
    /// `b` — the weight the cross-site loss/gradient reductions use.
    #[test]
    fn batch_len_counts_delta_rows() {
        let dense = Batch::Dense { x: Matrix::zeros(7, 3), y: Matrix::zeros(7, 2) };
        assert_eq!(dense.len(), 7);
        let seq = Batch::Seq { xs: vec![Matrix::zeros(4, 2); 5], y: Matrix::zeros(4, 2) };
        assert_eq!(seq.len(), 4);
        let tok = Batch::Tokens { b: 3, t: 6, ids: vec![0; 18], targets: vec![0; 18] };
        assert_eq!(tok.len(), 18);
        assert!(!tok.is_empty());
        let empty = Batch::Tokens { b: 0, t: 6, ids: vec![], targets: vec![] };
        assert!(empty.is_empty());
    }
}

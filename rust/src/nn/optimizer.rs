//! Optimizers over flat parameter lists. The paper trains everything with
//! Adam(lr=1e-4); every site runs the *same* optimizer on the *same* global
//! gradient, so replicas stay bit-identical without parameter broadcasts.

use crate::tensor::Matrix;

/// Adam with bias-corrected moments (Kingma & Ba), matching PyTorch defaults
/// except where the paper overrides them (lr = 1e-4).
pub struct Adam {
    /// Learning rate.
    pub lr: f32,
    /// First-moment decay.
    pub beta1: f32,
    /// Second-moment decay.
    pub beta2: f32,
    /// Denominator epsilon.
    pub eps: f32,
    t: u64,
    m: Vec<Matrix>,
    v: Vec<Matrix>,
}

impl Adam {
    /// Zero-moment state for parameters of the given shapes.
    pub fn new(lr: f32, shapes: &[(usize, usize)]) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect(),
            v: shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect(),
        }
    }

    /// Paper configuration: Adam with fixed lr 1e-4.
    pub fn paper(shapes: &[(usize, usize)]) -> Self {
        Adam::new(1e-4, shapes)
    }

    /// Updates applied so far.
    pub fn step_count(&self) -> u64 {
        self.t
    }

    /// Moment tables `(m, v)` for checkpointing; together with
    /// [`Adam::step_count`] this is the optimizer's entire mutable state.
    pub fn moments(&self) -> (&[Matrix], &[Matrix]) {
        (&self.m, &self.v)
    }

    /// Rebuild an optimizer mid-run from checkpointed state. `m` and `v`
    /// must be parallel per-parameter moment tables, `t` the number of
    /// updates already applied. Hyperparameters are the defaults (override
    /// the public fields afterwards if a run customized them).
    pub fn from_state(lr: f32, t: u64, m: Vec<Matrix>, v: Vec<Matrix>) -> Self {
        assert_eq!(m.len(), v.len(), "moment tables must be parallel");
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t, m, v }
    }

    /// One update step. `params[i] -= lr * mhat / (sqrt(vhat)+eps)`.
    pub fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        let _s = crate::obs::trace::phase_span("adam", crate::obs::trace::Phase::Compute);
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for ((p, g), (m, v)) in
            params.iter_mut().zip(grads).zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            assert_eq!(p.shape(), g.shape(), "optimizer shape mismatch");
            let (b1, b2, eps, lr) = (self.beta1, self.beta2, self.eps, self.lr);
            let pd = p.data_mut();
            let md = m.data_mut();
            let vd = v.data_mut();
            let gd = g.data();
            // Zipped iteration: no bounds checks in the 4-array hot loop.
            for (((pi, mi), vi), &gi) in
                pd.iter_mut().zip(md.iter_mut()).zip(vd.iter_mut()).zip(gd)
            {
                *mi = b1 * *mi + (1.0 - b1) * gi;
                *vi = b2 * *vi + (1.0 - b2) * gi * gi;
                let mhat = *mi / b1t;
                let vhat = *vi / b2t;
                *pi -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
    }
}

/// Plain SGD (used by ablation benches and the PowerSGD baseline's default).
pub struct Sgd {
    /// Learning rate.
    pub lr: f32,
    /// Momentum coefficient (0 disables velocity state).
    pub momentum: f32,
    vel: Option<Vec<Matrix>>,
}

impl Sgd {
    /// Fresh optimizer (velocity lazily allocated on first step).
    pub fn new(lr: f32, momentum: f32) -> Self {
        Sgd { lr, momentum, vel: None }
    }

    /// One (momentum-)SGD update step.
    pub fn step(&mut self, params: &mut [Matrix], grads: &[Matrix]) {
        assert_eq!(params.len(), grads.len());
        if self.momentum == 0.0 {
            for (p, g) in params.iter_mut().zip(grads) {
                p.axpy(-self.lr, g);
            }
            return;
        }
        let vel = self
            .vel
            .get_or_insert_with(|| grads.iter().map(|g| Matrix::zeros(g.rows(), g.cols())).collect());
        for ((p, g), v) in params.iter_mut().zip(grads).zip(vel.iter_mut()) {
            v.scale_inplace(self.momentum);
            v.axpy(1.0, g);
            p.axpy(-self.lr, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    /// Adam must minimize a simple quadratic.
    #[test]
    fn adam_minimizes_quadratic() {
        let mut rng = Rng::new(1);
        let target = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut p = vec![Matrix::zeros(4, 4)];
        let mut opt = Adam::new(0.05, &[(4, 4)]);
        for _ in 0..500 {
            let g = p[0].sub(&target);
            opt.step(&mut p, &[g]);
        }
        assert!(p[0].max_abs_diff(&target) < 0.05);
    }

    #[test]
    fn adam_first_step_size_is_lr() {
        // With bias correction, |Δp| of step 1 == lr regardless of grad scale.
        let mut p = vec![Matrix::filled(1, 1, 1.0)];
        let mut opt = Adam::new(1e-2, &[(1, 1)]);
        opt.step(&mut p, &[Matrix::filled(1, 1, 123.0)]);
        assert!((p[0][(0, 0)] - (1.0 - 1e-2)).abs() < 1e-5);
    }

    #[test]
    fn adam_deterministic() {
        let g = Matrix::from_vec(1, 2, vec![0.5, -0.25]);
        let mut p1 = vec![Matrix::zeros(1, 2)];
        let mut p2 = vec![Matrix::zeros(1, 2)];
        let mut o1 = Adam::paper(&[(1, 2)]);
        let mut o2 = Adam::paper(&[(1, 2)]);
        for _ in 0..10 {
            o1.step(&mut p1, std::slice::from_ref(&g));
            o2.step(&mut p2, std::slice::from_ref(&g));
        }
        assert_eq!(p1[0], p2[0]);
    }

    #[test]
    fn adam_from_state_continues_bit_identically() {
        let g = Matrix::from_vec(1, 2, vec![0.5, -0.25]);
        let mut p_full = vec![Matrix::zeros(1, 2)];
        let mut o_full = Adam::paper(&[(1, 2)]);
        for _ in 0..10 {
            o_full.step(&mut p_full, std::slice::from_ref(&g));
        }
        // Split run: 4 steps, snapshot, restore, 6 more.
        let mut p = vec![Matrix::zeros(1, 2)];
        let mut o = Adam::paper(&[(1, 2)]);
        for _ in 0..4 {
            o.step(&mut p, std::slice::from_ref(&g));
        }
        let (m, v) = o.moments();
        let mut o2 = Adam::from_state(o.lr, o.step_count(), m.to_vec(), v.to_vec());
        for _ in 0..6 {
            o2.step(&mut p, std::slice::from_ref(&g));
        }
        assert_eq!(p[0], p_full[0]);
    }

    #[test]
    fn sgd_momentum_accelerates() {
        let target = Matrix::filled(1, 1, 2.0);
        let run = |mom: f32| {
            let mut p = vec![Matrix::zeros(1, 1)];
            let mut opt = Sgd::new(0.01, mom);
            for _ in 0..100 {
                let g = p[0].sub(&target);
                opt.step(&mut p, &[g]);
            }
            (p[0][(0, 0)] - 2.0).abs()
        };
        assert!(run(0.9) < run(0.0));
    }
}

//! The paper's shared statistic: per-parameter (A, Δ) factor pairs.
//!
//! For every dense parameter W (h_in x h_out) touched by a batch, reverse AD
//! yields an input-activation stack A (N' x h_in) and a delta stack
//! Δ (N' x h_out) with   grad W = scale * Aᵀ Δ   (paper eq. 4). N' is the
//! batch size for feed-forward layers and T*N for unrolled recurrent weights
//! (section 3.5). dAD ships these stacks; edAD ships only A-stacks (+ small
//! model-specific aux activations) and the output delta; rank-dAD ships
//! low-rank factors of the same outer product.

use crate::tensor::{matmul_tn, Matrix, Workspace};

/// AD statistics for one dense parameter.
#[derive(Clone, Debug)]
pub struct StatsEntry {
    /// Index of the weight matrix in the model's flat parameter list.
    pub w_idx: usize,
    /// Index of the bias (grad = scale * colsum(Δ)); biases ride along with
    /// the deltas and cost no extra communication under dAD/edAD.
    pub b_idx: Option<usize>,
    /// Input-activation stack (N', h_in).
    pub a: Matrix,
    /// Delta stack (N', h_out), UNSCALED.
    pub d: Matrix,
}

impl StatsEntry {
    /// grad W = scale * Aᵀ Δ.
    pub fn weight_grad(&self, scale: f32) -> Matrix {
        let mut g = matmul_tn(&self.a, &self.d);
        g.scale_inplace(scale);
        g
    }

    /// grad b = scale * 1ᵀ Δ (row vector 1 x h_out).
    pub fn bias_grad(&self, scale: f32) -> Matrix {
        let sums = self.d.col_sums();
        Matrix::from_vec(1, sums.len(), sums).scale(scale)
    }

    /// Bytes to ship both factors (dAD's per-layer site->aggregator cost).
    pub fn wire_bytes(&self) -> u64 {
        self.a.wire_bytes() + self.d.wire_bytes()
    }
}

/// Everything one site produces for one batch.
#[derive(Clone, Debug)]
pub struct LocalStats {
    /// Mean loss over the site's batch.
    pub loss: f32,
    /// Factor pairs for the dense parameters (the dAD payload).
    pub entries: Vec<StatsEntry>,
    /// Extra activations edAD must broadcast to recompute deltas at the
    /// aggregated level (empty for MLPs; gate activations for GRUs).
    pub aux: Vec<Matrix>,
    /// Gradients for parameters with no outer-product form (embeddings,
    /// layer norms); exchanged dSGD-style by every algorithm.
    pub direct: Vec<(usize, Matrix)>,
}

impl LocalStats {
    /// A zero-loss, zero-entry stats object — the reusable target of
    /// `DistModel::local_stats_into`.
    pub fn empty() -> Self {
        LocalStats { loss: 0.0, entries: Vec::new(), aux: Vec::new(), direct: Vec::new() }
    }

    /// Return every matrix to `ws` and clear the containers *in place*
    /// (capacity kept). Calling this at the top of `local_stats_into` is
    /// what closes the steady-state allocation loop: last step's stacks
    /// become this step's buffers.
    pub fn recycle_into(&mut self, ws: &mut Workspace) {
        for e in self.entries.drain(..) {
            ws.recycle(e.a);
            ws.recycle(e.d);
        }
        for a in self.aux.drain(..) {
            ws.recycle(a);
        }
        for (_, g) in self.direct.drain(..) {
            ws.recycle(g);
        }
        self.loss = 0.0;
    }

    /// Assemble the full gradient list (aligned with the model's parameter
    /// list) from statistics. `scale` is 1/(S*N_per_site*...) — whatever
    /// converts unscaled delta sums into the global-mean gradient.
    pub fn assemble_grads(
        &self,
        shapes: &[(usize, usize)],
        scale: f32,
        direct_scale: f32,
    ) -> Vec<Matrix> {
        assemble_grads(shapes, &self.entries, &self.direct, scale, direct_scale)
    }
}

/// Gradient assembly shared by all algorithms: outer products for stats
/// entries, pass-through (scaled) for direct grads, zeros elsewhere.
pub fn assemble_grads(
    shapes: &[(usize, usize)],
    entries: &[StatsEntry],
    direct: &[(usize, Matrix)],
    scale: f32,
    direct_scale: f32,
) -> Vec<Matrix> {
    let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
    for e in entries {
        grads[e.w_idx] = e.weight_grad(scale);
        if let Some(bi) = e.b_idx {
            grads[bi] = e.bias_grad(scale);
        }
    }
    for (idx, g) in direct {
        let mut g = g.clone();
        g.scale_inplace(direct_scale);
        grads[*idx] = g;
    }
    grads
}

/// Concatenate per-site stats along the batch dimension — the aggregator's
/// `vertcat` (Algorithms 1-2). Entry lists must be congruent across sites.
pub fn concat_stats(site_stats: &[&[StatsEntry]]) -> Vec<StatsEntry> {
    assert!(!site_stats.is_empty());
    let n_entries = site_stats[0].len();
    (0..n_entries)
        .map(|i| {
            let a_parts: Vec<&Matrix> = site_stats.iter().map(|s| &s[i].a).collect();
            let d_parts: Vec<&Matrix> = site_stats.iter().map(|s| &s[i].d).collect();
            StatsEntry {
                w_idx: site_stats[0][i].w_idx,
                b_idx: site_stats[0][i].b_idx,
                a: Matrix::vertcat(&a_parts),
                d: Matrix::vertcat(&d_parts),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn weight_grad_is_scaled_outer() {
        let mut rng = Rng::new(1);
        let e = StatsEntry {
            w_idx: 0,
            b_idx: None,
            a: Matrix::randn(8, 5, 1.0, &mut rng),
            d: Matrix::randn(8, 3, 1.0, &mut rng),
        };
        let g = e.weight_grad(0.5);
        let want = matmul_tn(&e.a, &e.d).scale(0.5);
        assert!(g.max_abs_diff(&want) < 1e-6);
        assert_eq!(g.shape(), (5, 3));
    }

    #[test]
    fn concat_linearity_of_grad() {
        // grad(concat) == sum of per-site grads — the dAD exactness identity.
        let mut rng = Rng::new(2);
        let mk = |rng: &mut Rng| StatsEntry {
            w_idx: 0,
            b_idx: Some(1),
            a: Matrix::randn(4, 6, 1.0, rng),
            d: Matrix::randn(4, 2, 1.0, rng),
        };
        let s1 = vec![mk(&mut rng)];
        let s2 = vec![mk(&mut rng)];
        let cat = concat_stats(&[&s1, &s2]);
        assert_eq!(cat[0].a.shape(), (8, 6));
        let g_cat = cat[0].weight_grad(1.0);
        let mut g_sum = s1[0].weight_grad(1.0);
        g_sum.axpy(1.0, &s2[0].weight_grad(1.0));
        assert!(g_cat.max_abs_diff(&g_sum) < 1e-5);
        let b_cat = cat[0].bias_grad(1.0);
        let mut b_sum = s1[0].bias_grad(1.0);
        b_sum.axpy(1.0, &s2[0].bias_grad(1.0));
        assert!(b_cat.max_abs_diff(&b_sum) < 1e-5);
    }

    #[test]
    fn assemble_fills_all_shapes() {
        let mut rng = Rng::new(3);
        let entries = vec![StatsEntry {
            w_idx: 0,
            b_idx: Some(1),
            a: Matrix::randn(4, 5, 1.0, &mut rng),
            d: Matrix::randn(4, 3, 1.0, &mut rng),
        }];
        let direct = vec![(2usize, Matrix::filled(2, 2, 4.0))];
        let shapes = [(5, 3), (1, 3), (2, 2)];
        let grads = assemble_grads(&shapes, &entries, &direct, 1.0, 0.5);
        assert_eq!(grads.len(), 3);
        assert_eq!(grads[0].shape(), (5, 3));
        assert_eq!(grads[2][(0, 0)], 2.0);
    }

    #[test]
    fn wire_bytes_counts_both_factors() {
        let e = StatsEntry {
            w_idx: 0,
            b_idx: None,
            a: Matrix::zeros(32, 784),
            d: Matrix::zeros(32, 1024),
        };
        assert_eq!(e.wire_bytes(), (32 * 784 + 32 * 1024) as u64 * 4);
    }
}

//! Decoder-only transformer LM with a fully manual backward pass exposing
//! per-linear (A, Δ) statistics — the paper's method applied to the
//! architecture its section 5.3.2 mentions ("as well as transformers").
//!
//! dAD covers every dense projection (W_qkv, W_o, W_fc1, W_fc2, lm_head);
//! embeddings, positional table and LayerNorm gains/biases have no
//! outer-product factorization, so their (small) gradients travel in
//! `LocalStats::direct`, dSGD-style — analogous to the paper's observation
//! that convolutions need special treatment. edAD is not defined through
//! attention (the softmax mixes rows), so `edad_recompute` returns None and
//! the coordinator falls back to dAD for this architecture.

use crate::nn::init::normal;
use crate::nn::loss::softmax_xent_into;
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::{LocalStats, StatsEntry};
use crate::tensor::{matmul, matmul_nt, Matrix, Rng, Workspace};

/// Transformer hyperparameters.
#[derive(Clone, Debug)]
pub struct TransformerConfig {
    /// Vocabulary size.
    pub vocab: usize,
    /// Residual-stream width.
    pub d_model: usize,
    /// Attention heads per block.
    pub n_heads: usize,
    /// Decoder blocks.
    pub n_layers: usize,
    /// Feed-forward hidden width.
    pub d_ff: usize,
    /// Maximum (and trained) sequence length.
    pub max_t: usize,
}

impl TransformerConfig {
    /// Unit-test-sized configuration.
    pub fn tiny() -> Self {
        TransformerConfig { vocab: 11, d_model: 8, n_heads: 2, n_layers: 2, d_ff: 16, max_t: 6 }
    }

    /// ~12.8M parameters: the `--dataset lm` default scale (see
    /// EXPERIMENTS.md for why the session substitutes this for a 100M
    /// model on a CPU-only testbed).
    pub fn e2e() -> Self {
        TransformerConfig { vocab: 512, d_model: 320, n_heads: 8, n_layers: 10, d_ff: 1280, max_t: 64 }
    }

    /// ~100M parameters (GPT-2-small shape): the `--dataset lm --scale
    /// paper` configuration. Hours per epoch on a CPU-only testbed — use
    /// it deliberately.
    pub fn big() -> Self {
        TransformerConfig {
            vocab: 32_000,
            d_model: 768,
            n_heads: 12,
            n_layers: 12,
            d_ff: 3072,
            max_t: 128,
        }
    }

    /// Total scalar parameter count implied by the config.
    pub fn n_params(&self) -> usize {
        let d = self.d_model;
        let per_block = d * 3 * d + 3 * d + d * d + d + 2 * d + d * self.d_ff + self.d_ff
            + self.d_ff * d + d + 2 * d;
        self.vocab * d + self.max_t * d + self.n_layers * per_block + 2 * d + d * self.vocab
    }
}

/// Parameter indices per block (offsets into the flat list).
const BLOCK_PARAMS: usize = 12;

/// Decoder-only transformer LM with the reverse-AD backward exposed as
/// (A, Δ) statistics for its dense projections.
#[derive(Clone)]
pub struct Transformer {
    /// Hyperparameters.
    pub cfg: TransformerConfig,
    /// Flat parameter list; layout documented in `param_layout`.
    params: Vec<Matrix>,
}

/// Saved forward state for backward.
struct Saved {
    x0: Matrix, // embed+pos (rows = B*T)
    per_block: Vec<BlockSaved>,
    hf: Matrix,         // final LN output
    lnf: LnSaved,       // final LN stats
    x_final: Matrix,    // input of final LN
    logits: Matrix,     // (B*T, V)
}

struct BlockSaved {
    ln1: LnSaved,   // LN1 stats
    h1: Matrix,     // LN1 output
    q: Matrix,      // (B*T, D)
    k: Matrix,
    v: Matrix,
    probs: Vec<Matrix>, // per (b, head): (T, T) causal softmax rows
    ctx: Matrix,        // concatenated heads (B*T, D)
    ln2: LnSaved,
    h2: Matrix,         // LN2 output
    f: Matrix,          // relu(fc1) output (B*T, F)
}

struct LnSaved {
    xhat: Matrix,
    rstd: Vec<f32>,
}

fn layer_norm(x: &Matrix, g: &Matrix, b: &Matrix) -> (Matrix, LnSaved) {
    let (n, d) = x.shape();
    let mut out = Matrix::zeros(n, d);
    let mut xhat = Matrix::zeros(n, d);
    let mut rstd = vec![0.0f32; n];
    let eps = 1e-5f32;
    for i in 0..n {
        let row = x.row(i);
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let r = 1.0 / (var + eps).sqrt();
        rstd[i] = r;
        for j in 0..d {
            let xh = (row[j] - mean) * r;
            xhat[(i, j)] = xh;
            out[(i, j)] = g[(0, j)] * xh + b[(0, j)];
        }
    }
    (out, LnSaved { xhat, rstd })
}

/// LayerNorm backward: returns (dx, dg, db).
fn layer_norm_backward(dy: &Matrix, g: &Matrix, saved: &LnSaved) -> (Matrix, Matrix, Matrix) {
    let (n, d) = dy.shape();
    let mut dx = Matrix::zeros(n, d);
    let mut dg = Matrix::zeros(1, d);
    let mut db = Matrix::zeros(1, d);
    for i in 0..n {
        let mut sum_dyg = 0.0f32;
        let mut sum_dyg_xhat = 0.0f32;
        for j in 0..d {
            let dyg = dy[(i, j)] * g[(0, j)];
            sum_dyg += dyg;
            sum_dyg_xhat += dyg * saved.xhat[(i, j)];
            dg[(0, j)] += dy[(i, j)] * saved.xhat[(i, j)];
            db[(0, j)] += dy[(i, j)];
        }
        let m1 = sum_dyg / d as f32;
        let m2 = sum_dyg_xhat / d as f32;
        for j in 0..d {
            let dyg = dy[(i, j)] * g[(0, j)];
            dx[(i, j)] = saved.rstd[i] * (dyg - m1 - saved.xhat[(i, j)] * m2);
        }
    }
    (dx, dg, db)
}

fn add_bias_rows(z: &mut Matrix, b: &Matrix) {
    for i in 0..z.rows() {
        for (v, &bv) in z.row_mut(i).iter_mut().zip(b.row(0)) {
            *v += bv;
        }
    }
}

impl Transformer {
    /// Parameter layout:
    ///   0: embed (V, D)      1: pos (max_t, D)
    ///   per block k (base = 2 + k*12):
    ///     +0 W_qkv (D,3D) +1 b_qkv  +2 W_o (D,D) +3 b_o
    ///     +4 ln1_g +5 ln1_b  +6 W_fc1 (D,F) +7 b_fc1
    ///     +8 W_fc2 (F,D) +9 b_fc2  +10 ln2_g +11 ln2_b
    ///   tail (base = 2 + L*12): +0 lnf_g +1 lnf_b +2 lm_head (D,V)
    pub fn new(cfg: TransformerConfig, rng: &mut Rng) -> Self {
        let d = cfg.d_model;
        let std = 0.02f32;
        let mut params = vec![
            normal(cfg.vocab, d, std, rng),
            normal(cfg.max_t, d, std, rng),
        ];
        let resid_std = std / (2.0 * cfg.n_layers as f32).sqrt();
        for _ in 0..cfg.n_layers {
            params.push(normal(d, 3 * d, std, rng)); // W_qkv
            params.push(Matrix::zeros(1, 3 * d));
            params.push(normal(d, d, resid_std, rng)); // W_o
            params.push(Matrix::zeros(1, d));
            params.push(Matrix::filled(1, d, 1.0)); // ln1_g
            params.push(Matrix::zeros(1, d));
            params.push(normal(d, cfg.d_ff, std, rng)); // W_fc1
            params.push(Matrix::zeros(1, cfg.d_ff));
            params.push(normal(cfg.d_ff, d, resid_std, rng)); // W_fc2
            params.push(Matrix::zeros(1, d));
            params.push(Matrix::filled(1, d, 1.0)); // ln2_g
            params.push(Matrix::zeros(1, d));
        }
        params.push(Matrix::filled(1, d, 1.0)); // lnf_g
        params.push(Matrix::zeros(1, d));
        params.push(normal(d, cfg.vocab, std, rng)); // lm_head
        Transformer { cfg, params }
    }

    fn block_base(&self, k: usize) -> usize {
        2 + k * BLOCK_PARAMS
    }

    fn tail_base(&self) -> usize {
        2 + self.cfg.n_layers * BLOCK_PARAMS
    }

    fn forward(&self, b: usize, t: usize, ids: &[u32]) -> Saved {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let rows = b * t;
        assert!(t <= cfg.max_t);
        let embed = &self.params[0];
        let pos = &self.params[1];
        let mut x = Matrix::zeros(rows, d);
        for r in 0..rows {
            let tok = ids[r] as usize;
            let tt = r % t;
            for j in 0..d {
                x[(r, j)] = embed[(tok, j)] + pos[(tt, j)];
            }
        }
        let x0 = x.clone();
        let mut per_block = Vec::with_capacity(cfg.n_layers);
        for kblock in 0..cfg.n_layers {
            let base = self.block_base(kblock);
            let (w_qkv, b_qkv) = (&self.params[base], &self.params[base + 1]);
            let (w_o, b_o) = (&self.params[base + 2], &self.params[base + 3]);
            let (g1, bb1) = (&self.params[base + 4], &self.params[base + 5]);
            let (w_fc1, b_fc1) = (&self.params[base + 6], &self.params[base + 7]);
            let (w_fc2, b_fc2) = (&self.params[base + 8], &self.params[base + 9]);
            let (g2, bb2) = (&self.params[base + 10], &self.params[base + 11]);

            let (h1, ln1) = layer_norm(&x, g1, bb1);
            let mut qkv = matmul(&h1, w_qkv);
            add_bias_rows(&mut qkv, b_qkv);
            let dh = d / cfg.n_heads;
            let scale = 1.0 / (dh as f32).sqrt();
            // Split q/k/v.
            let mut q = Matrix::zeros(rows, d);
            let mut k = Matrix::zeros(rows, d);
            let mut v = Matrix::zeros(rows, d);
            for r in 0..rows {
                q.row_mut(r).copy_from_slice(&qkv.row(r)[0..d]);
                k.row_mut(r).copy_from_slice(&qkv.row(r)[d..2 * d]);
                v.row_mut(r).copy_from_slice(&qkv.row(r)[2 * d..3 * d]);
            }
            // Causal attention per (batch, head).
            let mut ctx = Matrix::zeros(rows, d);
            let mut probs = Vec::with_capacity(b * cfg.n_heads);
            for bi in 0..b {
                let r0 = bi * t;
                for hh in 0..cfg.n_heads {
                    let c0 = hh * dh;
                    // scores (T,T), causal.
                    let mut p = Matrix::zeros(t, t);
                    for ti in 0..t {
                        let qrow = &q.row(r0 + ti)[c0..c0 + dh];
                        let mut mx = f32::NEG_INFINITY;
                        for tj in 0..=ti {
                            let krow = &k.row(r0 + tj)[c0..c0 + dh];
                            let s = crate::tensor::dot(qrow, krow) * scale;
                            p[(ti, tj)] = s;
                            mx = mx.max(s);
                        }
                        let mut sum = 0.0f32;
                        for tj in 0..=ti {
                            let e = (p[(ti, tj)] - mx).exp();
                            p[(ti, tj)] = e;
                            sum += e;
                        }
                        let inv = 1.0 / sum;
                        for tj in 0..=ti {
                            p[(ti, tj)] *= inv;
                        }
                        // ctx row
                        for jj in 0..dh {
                            let mut acc = 0.0f32;
                            for tj in 0..=ti {
                                acc += p[(ti, tj)] * v.row(r0 + tj)[c0 + jj];
                            }
                            ctx[(r0 + ti, c0 + jj)] = acc;
                        }
                    }
                    probs.push(p);
                }
            }
            let mut o = matmul(&ctx, w_o);
            add_bias_rows(&mut o, b_o);
            x = x.add(&o);
            let (h2, ln2) = layer_norm(&x, g2, bb2);
            let mut f = matmul(&h2, w_fc1);
            add_bias_rows(&mut f, b_fc1);
            f.map_inplace(|v| v.max(0.0));
            let mut m = matmul(&f, w_fc2);
            add_bias_rows(&mut m, b_fc2);
            x = x.add(&m);
            per_block.push(BlockSaved { ln1, h1, q, k, v, probs, ctx, ln2, h2, f });
        }
        let tb = self.tail_base();
        let x_final = x.clone();
        let (hf, lnf) = layer_norm(&x, &self.params[tb], &self.params[tb + 1]);
        let logits = matmul(&hf, &self.params[tb + 2]);
        Saved { x0, per_block, hf, lnf, x_final, logits }
    }

    /// Mean next-token cross-entropy of a token batch.
    pub fn loss(&self, batch: &Batch) -> f32 {
        self.local_stats(batch).loss
    }
}

impl DistModel for Transformer {
    fn param_shapes(&self) -> Vec<(usize, usize)> {
        self.params.iter().map(|p| p.shape()).collect()
    }

    fn params(&self) -> Vec<&Matrix> {
        self.params.iter().collect()
    }

    fn params_mut(&mut self) -> Vec<&mut Matrix> {
        self.params.iter_mut().collect()
    }

    /// Workspace-threaded entry point. The loss head (one-hot targets,
    /// softmax delta) runs on arena buffers; the attention tape itself is
    /// still allocation-bound — per-block buffers are sized by (B, T, D)
    /// and dominated by the O(B·H·T²) attention math, left for a future
    /// flash-style rewrite (EXPERIMENTS.md §Perf).
    fn local_stats_into(&self, batch: &Batch, arena: &mut Workspace, out: &mut LocalStats) {
        let (b, t, ids, targets) = match batch {
            Batch::Tokens { b, t, ids, targets } => (*b, *t, ids, targets),
            _ => panic!("Transformer consumes token batches"),
        };
        out.recycle_into(arena);
        let cfg = self.cfg.clone();
        let d = cfg.d_model;
        let rows = b * t;
        let saved = self.forward(b, t, ids);

        // Loss + output delta (UNSCALED p - y, matching the other models).
        let mut y = arena.take(rows, cfg.vocab);
        for (i, &tv) in targets.iter().enumerate() {
            y[(i, tv as usize)] = 1.0;
        }
        let mut d_logits = arena.take(rows, cfg.vocab);
        let loss = softmax_xent_into(&saved.logits, &y, &mut d_logits);
        arena.recycle(y);

        let entries = &mut out.entries;
        let direct = &mut out.direct;
        let tb = self.tail_base();

        // Backprop into final LN, then hand Δ_logits to the lm_head entry.
        let d_hf = matmul_nt(&d_logits, &self.params[tb + 2]);
        // lm_head: A = hf, Δ = d_logits.
        entries.push(StatsEntry { w_idx: tb + 2, b_idx: None, a: saved.hf.clone(), d: d_logits });
        let (mut dx, dgf, dbf) = layer_norm_backward(&d_hf, &self.params[tb], &saved.lnf);
        direct.push((tb, dgf));
        direct.push((tb + 1, dbf));
        let _ = &saved.x_final;

        let dh = d / cfg.n_heads;
        let scale = 1.0 / (dh as f32).sqrt();
        for kblock in (0..cfg.n_layers).rev() {
            let base = self.block_base(kblock);
            let bs = &saved.per_block[kblock];
            let (w_o, _b_o) = (&self.params[base + 2], &self.params[base + 3]);
            let (g1, _bb1) = (&self.params[base + 4], &self.params[base + 5]);
            let (w_fc1, _) = (&self.params[base + 6], &self.params[base + 7]);
            let (w_fc2, _) = (&self.params[base + 8], &self.params[base + 9]);
            let (g2, _bb2) = (&self.params[base + 10], &self.params[base + 11]);

            // ---- MLP sub-block backward (x = x_mid + fc2(relu(fc1(LN2 x_mid))))
            let d_m = dx.clone(); // gradient wrt fc2 output (residual passthrough)
            entries.push(StatsEntry { w_idx: base + 8, b_idx: Some(base + 9), a: bs.f.clone(), d: d_m.clone() });
            let mut d_f = matmul_nt(&d_m, w_fc2);
            // relu mask from output f.
            for (dv, &fv) in d_f.data_mut().iter_mut().zip(bs.f.data()) {
                if fv <= 0.0 {
                    *dv = 0.0;
                }
            }
            entries.push(StatsEntry { w_idx: base + 6, b_idx: Some(base + 7), a: bs.h2.clone(), d: d_f.clone() });
            let d_h2 = matmul_nt(&d_f, w_fc1);
            let (d_xmid_ln, dg2, db2) = layer_norm_backward(&d_h2, g2, &bs.ln2);
            direct.push((base + 10, dg2));
            direct.push((base + 11, db2));
            let d_xmid = dx.add(&d_xmid_ln); // residual + LN path

            // ---- Attention sub-block backward (x_mid = x_in + W_o ctx)
            let d_o = d_xmid.clone();
            entries.push(StatsEntry { w_idx: base + 2, b_idx: Some(base + 3), a: bs.ctx.clone(), d: d_o.clone() });
            let d_ctx = matmul_nt(&d_o, w_o);
            // Attention backward per (batch, head).
            let mut d_q = Matrix::zeros(rows, d);
            let mut d_k = Matrix::zeros(rows, d);
            let mut d_v = Matrix::zeros(rows, d);
            for bi in 0..b {
                let r0 = bi * t;
                for hh in 0..cfg.n_heads {
                    let c0 = hh * dh;
                    let p = &bs.probs[bi * cfg.n_heads + hh];
                    // dP = d_ctx V^T ; dV = P^T d_ctx (within the head cols)
                    for ti in 0..t {
                        // dP row + softmax backward
                        let mut dp = vec![0.0f32; ti + 1];
                        for tj in 0..=ti {
                            let vrow = &bs.v.row(r0 + tj)[c0..c0 + dh];
                            let drow = &d_ctx.row(r0 + ti)[c0..c0 + dh];
                            dp[tj] = crate::tensor::dot(vrow, drow);
                        }
                        let dot_pd: f32 =
                            (0..=ti).map(|tj| dp[tj] * p[(ti, tj)]).sum();
                        for tj in 0..=ti {
                            let ds = p[(ti, tj)] * (dp[tj] - dot_pd); // softmax bwd
                            // dQ[ti] += ds * K[tj] * scale ; dK[tj] += ds * Q[ti] * scale
                            for jj in 0..dh {
                                d_q[(r0 + ti, c0 + jj)] += ds * bs.k[(r0 + tj, c0 + jj)] * scale;
                                d_k[(r0 + tj, c0 + jj)] += ds * bs.q[(r0 + ti, c0 + jj)] * scale;
                            }
                            // dV[tj] += P[ti,tj] * d_ctx[ti]
                            for jj in 0..dh {
                                d_v[(r0 + tj, c0 + jj)] += p[(ti, tj)] * d_ctx[(r0 + ti, c0 + jj)];
                            }
                        }
                    }
                }
            }
            // Assemble d_qkv (rows, 3D).
            let mut d_qkv = Matrix::zeros(rows, 3 * d);
            for r in 0..rows {
                d_qkv.row_mut(r)[0..d].copy_from_slice(d_q.row(r));
                d_qkv.row_mut(r)[d..2 * d].copy_from_slice(d_k.row(r));
                d_qkv.row_mut(r)[2 * d..3 * d].copy_from_slice(d_v.row(r));
            }
            entries.push(StatsEntry { w_idx: base, b_idx: Some(base + 1), a: bs.h1.clone(), d: d_qkv.clone() });
            let d_h1 = matmul_nt(&d_qkv, &self.params[base]);
            let (d_xin_ln, dg1, db1) = layer_norm_backward(&d_h1, g1, &bs.ln1);
            direct.push((base + 4, dg1));
            direct.push((base + 5, db1));
            dx = d_xmid.add(&d_xin_ln);
        }

        // Embedding + positional gradients (scatter-add of dx over x0 rows).
        let mut d_embed = Matrix::zeros(cfg.vocab, d);
        let mut d_pos = Matrix::zeros(cfg.max_t, d);
        for r in 0..rows {
            let tok = ids[r] as usize;
            let tt = r % t;
            for j in 0..d {
                d_embed[(tok, j)] += dx[(r, j)];
                d_pos[(tt, j)] += dx[(r, j)];
            }
        }
        let _ = &saved.x0;
        direct.push((0, d_embed));
        direct.push((1, d_pos));

        // Entries were pushed head-first; reverse into forward order for
        // stable entry naming.
        entries.reverse();
        out.loss = loss;
    }

    fn predict(&self, batch: &Batch) -> Matrix {
        let (b, t, ids) = match batch {
            Batch::Tokens { b, t, ids, .. } => (*b, *t, ids),
            _ => panic!("Transformer consumes token batches"),
        };
        let saved = self.forward(b, t, ids);
        crate::nn::activations::softmax_rows(&saved.logits)
    }

    fn edad_recompute(
        &self,
        _a_hats: &[Matrix],
        _aux: &[Matrix],
        _delta_out: &Matrix,
        _site_rows: &[usize],
    ) -> Option<Vec<StatsEntry>> {
        None // attention mixes rows; the activation-derivative trick does not apply
    }

    fn supports_edad(&self) -> bool {
        false // see edad_recompute: coordinators reject edad up front
    }

    fn local_stats_entry_count(&self) -> usize {
        4 * self.cfg.n_layers + 1
    }

    fn entry_names(&self) -> Vec<String> {
        let mut names = Vec::new();
        for k in 0..self.cfg.n_layers {
            names.push(format!("block{k}-qkv"));
            names.push(format!("block{k}-attn_out"));
            names.push(format!("block{k}-fc1"));
            names.push(format!("block{k}-fc2"));
        }
        names.push("lm_head".to_string());
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn token_batch(rng: &mut Rng, cfg: &TransformerConfig, b: usize, t: usize) -> Batch {
        let ids: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        let targets: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
        Batch::Tokens { b, t, ids, targets }
    }

    /// Full-stack gradcheck: stats-assembled gradients vs finite differences
    /// across every parameter family (embeddings, LN, attention, MLP, head).
    #[test]
    fn grads_match_finite_difference() {
        let mut rng = Rng::new(31);
        let cfg = TransformerConfig::tiny();
        let model = Transformer::new(cfg.clone(), &mut rng);
        let batch = token_batch(&mut rng, &cfg, 2, 5);
        let rows = 10.0f32;
        let stats = model.local_stats(&batch);
        let shapes = model.param_shapes();
        let grads = stats.assemble_grads(&shapes, 1.0 / rows, 1.0 / rows);
        let loss_of = |m: &Transformer| m.local_stats(&batch).loss;
        let eps = 2e-2f32;
        for (pi, g) in grads.iter().enumerate() {
            let (r, c) = g.shape();
            for &(i, j) in &[(0usize, 0usize), (r / 2, c / 2), (r - 1, c - 1)] {
                let mut mp = model.clone();
                mp.params_mut()[pi][(i, j)] += eps;
                let mut mm = model.clone();
                mm.params_mut()[pi][(i, j)] -= eps;
                let fd = (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps);
                let an = g[(i, j)];
                assert!(
                    (fd - an).abs() < 4e-2 * (1.0 + an.abs().max(fd.abs())),
                    "param {pi} ({i},{j}): fd={fd} an={an}"
                );
            }
        }
    }

    #[test]
    fn causal_masking_no_future_leak() {
        // Changing a future token must not change earlier logits.
        let mut rng = Rng::new(5);
        let cfg = TransformerConfig::tiny();
        let model = Transformer::new(cfg.clone(), &mut rng);
        let t = 5;
        let ids: Vec<u32> = (0..t).map(|i| (i % cfg.vocab) as u32).collect();
        let mut ids2 = ids.clone();
        ids2[t - 1] = (ids[t - 1] + 1) % cfg.vocab as u32;
        let s1 = model.forward(1, t, &ids);
        let s2 = model.forward(1, t, &ids2);
        for r in 0..t - 1 {
            for j in 0..cfg.vocab {
                assert!(
                    (s1.logits[(r, j)] - s2.logits[(r, j)]).abs() < 1e-5,
                    "future token leaked into position {r}"
                );
            }
        }
    }

    #[test]
    fn loss_decreases_under_training() {
        use crate::nn::optimizer::Adam;
        let mut rng = Rng::new(6);
        let cfg = TransformerConfig::tiny();
        let mut model = Transformer::new(cfg.clone(), &mut rng);
        let batch = token_batch(&mut rng, &cfg, 4, 5);
        let shapes = model.param_shapes();
        let mut opt = Adam::new(3e-3, &shapes);
        let rows = 20.0f32;
        let first = model.loss(&batch);
        for _ in 0..40 {
            let stats = model.local_stats(&batch);
            let grads = stats.assemble_grads(&shapes, 1.0 / rows, 1.0 / rows);
            let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
            opt.step(&mut params, &grads);
            model.set_params(&params);
        }
        let last = model.loss(&batch);
        assert!(last < first * 0.8, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn param_count_formula() {
        let cfg = TransformerConfig::tiny();
        let model = Transformer::new(cfg.clone(), &mut rng_of(1));
        let total: usize = model.params().iter().map(|p| p.numel()).sum();
        assert_eq!(total, cfg.n_params());
    }

    fn rng_of(seed: u64) -> Rng {
        Rng::new(seed)
    }
}

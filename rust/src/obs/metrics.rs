//! Process-wide metrics registry: counters, gauges and a fixed-bucket
//! latency histogram over `AtomicU64`, rendered in Prometheus text
//! exposition format by [`render`].
//!
//! The registry is a fixed set of statics rather than a dynamic map: the
//! hot path (a counter add, a gauge store, a histogram observe) is a
//! handful of relaxed atomic ops with zero allocation, and the exposition
//! walk in [`render`] is a compile-time list that [`METRIC_NAMES`] (and
//! the `docs/FORMATS.md` drift gate in `tests/format_spec.rs`) can mirror
//! exactly.

use std::sync::atomic::{AtomicU64, Ordering};

/// A monotonically increasing counter.
pub struct Counter(AtomicU64);

impl Counter {
    /// A zeroed counter (const so it can live in a static).
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add `n` to the counter.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Reset to zero (tests and fresh runs only — Prometheus semantics
    /// treat resets as a restart).
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// An instantaneous integer gauge (step counter, live-site census, queue
/// depth).
pub struct Gauge(AtomicU64);

impl Gauge {
    /// A zeroed gauge (const so it can live in a static).
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    /// Replace the gauge value.
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Upper bounds (seconds) of the step-latency histogram buckets; a final
/// `+Inf` bucket is implicit. Spanning 0.5 ms – 10 s covers a quick-scale
/// sim step through a chaos-delayed wide-area round.
pub const LATENCY_BUCKETS_S: [f64; 14] = [
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
];

const NB: usize = LATENCY_BUCKETS_S.len();

/// Fixed-bucket latency histogram. `observe` is a linear bucket scan plus
/// three relaxed atomic adds — allocation-free and lock-free.
pub struct Histogram {
    buckets: [AtomicU64; NB],
    /// `+Inf` overflow bucket.
    inf: AtomicU64,
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    /// A zeroed histogram (const so it can live in a static).
    pub const fn new() -> Histogram {
        // `AtomicU64` is not Copy, so the bucket array is spelled out —
        // one zeroed cell per entry of `LATENCY_BUCKETS_S`.
        Histogram {
            buckets: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
            inf: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation of `seconds`.
    pub fn observe(&self, seconds: f64) {
        let mut placed = false;
        for (i, ub) in LATENCY_BUCKETS_S.iter().enumerate() {
            if seconds <= *ub {
                self.buckets[i].fetch_add(1, Ordering::Relaxed);
                placed = true;
                break;
            }
        }
        if !placed {
            self.inf.fetch_add(1, Ordering::Relaxed);
        }
        self.sum_ns.fetch_add((seconds * 1e9) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Estimate the `q`-quantile (0..=1) as the upper bound of the bucket
    /// containing it; observations past the last bound report that bound.
    /// Returns 0 with no observations.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return LATENCY_BUCKETS_S[i];
            }
        }
        LATENCY_BUCKETS_S[NB - 1]
    }

    /// Reset all buckets (tests and fresh runs only).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.inf.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }
}

/// Global training-step gauge (`dad_step`): epochs×steps completed by the
/// training loops, or requests served by the inference batcher.
pub static STEP: Gauge = Gauge::new();

/// Live-site census gauge (`dad_sites_live`), updated by the aggregator.
pub static SITES_LIVE: Gauge = Gauge::new();

/// Total site→aggregator (plus peer) bytes (`dad_bytes_up_total`).
pub static BYTES_UP: Counter = Counter::new();

/// Total aggregator→site bytes (`dad_bytes_down_total`).
pub static BYTES_DOWN: Counter = Counter::new();

/// Step wall-clock latency histogram (`dad_step_latency_seconds`).
pub static STEP_LATENCY: Histogram = Histogram::new();

/// Inference batcher queue depth at drain time
/// (`dad_batcher_queue_depth`).
pub static BATCHER_QUEUE_DEPTH: Gauge = Gauge::new();

/// This process's level in the aggregation tree (`dad_tree_level`): 0 at
/// the root aggregator, 1 at a `dad relay` sub-aggregator.
pub static TREE_LEVEL: Gauge = Gauge::new();

/// Live directly-connected child links (`dad_children_live`): leaf sites
/// or relay subtrees still answering this aggregation level.
pub static CHILDREN_LIVE: Gauge = Gauge::new();

/// Every metric name the `/metrics` endpoint exposes, in exposition
/// order. `tests/format_spec.rs` asserts each appears (backticked) in the
/// `docs/FORMATS.md` inventory so the spec cannot drift from the code.
pub const METRIC_NAMES: [&str; 10] = [
    "dad_step",
    "dad_sites_live",
    "dad_bytes_up_total",
    "dad_bytes_down_total",
    "dad_step_latency_seconds",
    "dad_step_latency_p50_seconds",
    "dad_step_latency_p99_seconds",
    "dad_batcher_queue_depth",
    "dad_tree_level",
    "dad_children_live",
];

/// Set the byte counters from a ledger census: counters are monotone, so
/// this records the *delta* since the last call per direction.
pub fn record_bytes(up_total: u64, down_total: u64) {
    let prev_up = BYTES_UP.get();
    if up_total > prev_up {
        BYTES_UP.add(up_total - prev_up);
    }
    let prev_down = BYTES_DOWN.get();
    if down_total > prev_down {
        BYTES_DOWN.add(down_total - prev_down);
    }
}

/// Reset every registered metric (test isolation and fresh serve runs).
pub fn reset_all() {
    STEP.set(0);
    SITES_LIVE.set(0);
    BYTES_UP.reset();
    BYTES_DOWN.reset();
    STEP_LATENCY.reset();
    BATCHER_QUEUE_DEPTH.set(0);
    TREE_LEVEL.set(0);
    CHILDREN_LIVE.set(0);
}

/// Render every metric in Prometheus text exposition format (version
/// 0.0.4): `# TYPE` headers, histogram `_bucket{le=...}` / `_sum` /
/// `_count` series, and derived p50/p99 gauges.
pub fn render() -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(1024);
    let _ = writeln!(out, "# TYPE dad_step gauge\ndad_step {}", STEP.get());
    let _ = writeln!(out, "# TYPE dad_sites_live gauge\ndad_sites_live {}", SITES_LIVE.get());
    let _ =
        writeln!(out, "# TYPE dad_bytes_up_total counter\ndad_bytes_up_total {}", BYTES_UP.get());
    let _ = writeln!(
        out,
        "# TYPE dad_bytes_down_total counter\ndad_bytes_down_total {}",
        BYTES_DOWN.get()
    );
    let _ = writeln!(out, "# TYPE dad_step_latency_seconds histogram");
    let mut cum = 0u64;
    for (i, ub) in LATENCY_BUCKETS_S.iter().enumerate() {
        cum += STEP_LATENCY.buckets[i].load(Ordering::Relaxed);
        let _ = writeln!(out, "dad_step_latency_seconds_bucket{{le=\"{ub}\"}} {cum}");
    }
    cum += STEP_LATENCY.inf.load(Ordering::Relaxed);
    let _ = writeln!(out, "dad_step_latency_seconds_bucket{{le=\"+Inf\"}} {cum}");
    let sum_s = STEP_LATENCY.sum_ns.load(Ordering::Relaxed) as f64 * 1e-9;
    let _ = writeln!(out, "dad_step_latency_seconds_sum {sum_s}");
    let _ = writeln!(out, "dad_step_latency_seconds_count {}", STEP_LATENCY.count());
    let _ = writeln!(
        out,
        "# TYPE dad_step_latency_p50_seconds gauge\ndad_step_latency_p50_seconds {}",
        STEP_LATENCY.quantile(0.50)
    );
    let _ = writeln!(
        out,
        "# TYPE dad_step_latency_p99_seconds gauge\ndad_step_latency_p99_seconds {}",
        STEP_LATENCY.quantile(0.99)
    );
    let _ = writeln!(
        out,
        "# TYPE dad_batcher_queue_depth gauge\ndad_batcher_queue_depth {}",
        BATCHER_QUEUE_DEPTH.get()
    );
    let _ = writeln!(out, "# TYPE dad_tree_level gauge\ndad_tree_level {}", TREE_LEVEL.get());
    let _ = writeln!(
        out,
        "# TYPE dad_children_live gauge\ndad_children_live {}",
        CHILDREN_LIVE.get()
    );
    out
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_land_in_the_right_bucket() {
        let h = Histogram::new();
        for _ in 0..98 {
            h.observe(0.002); // ≤ 0.0025 bucket
        }
        h.observe(0.3); // ≤ 0.5
        h.observe(20.0); // +Inf
        assert_eq!(h.count(), 100);
        assert_eq!(h.quantile(0.50), 0.0025);
        assert_eq!(h.quantile(0.99), 0.5);
    }

    #[test]
    fn render_is_well_formed_and_covers_every_name() {
        let text = render();
        for name in METRIC_NAMES {
            assert!(
                text.lines().any(|l| l.starts_with(name)),
                "render() emits no sample for {name}:\n{text}"
            );
        }
        // Every non-comment line is `name[{labels}] value` with a
        // parseable numeric value.
        for line in text.lines() {
            if line.starts_with('#') {
                let mut parts = line.splitn(4, ' ');
                assert_eq!(parts.next(), Some("#"));
                assert_eq!(parts.next(), Some("TYPE"));
                assert!(parts.next().is_some_and(|n| n.starts_with("dad_")));
                assert!(matches!(parts.next(), Some("gauge" | "counter" | "histogram")));
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("sample line has no value");
            assert!(name.starts_with("dad_"), "unexpected metric family: {line}");
            assert!(value.parse::<f64>().is_ok(), "unparseable sample value: {line}");
        }
    }

    #[test]
    fn record_bytes_is_delta_based_and_monotone() {
        // Not reset-isolated from other tests, so assert on deltas only.
        let before_up = BYTES_UP.get();
        let before_down = BYTES_DOWN.get();
        record_bytes(before_up + 100, before_down + 40);
        record_bytes(before_up + 100, before_down + 40); // same census: no-op
        assert_eq!(BYTES_UP.get(), before_up + 100);
        assert_eq!(BYTES_DOWN.get(), before_down + 40);
        record_bytes(before_up + 150, before_down + 41);
        assert_eq!(BYTES_UP.get(), before_up + 150);
        assert_eq!(BYTES_DOWN.get(), before_down + 41);
    }
}

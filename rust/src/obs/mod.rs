//! Time-domain observability: where does a training step's wall-clock go?
//!
//! The [`crate::dist::ledger::Ledger`] answers the *bytes* question; this
//! module answers the *seconds* question with three zero-dependency
//! layers, matching the repo's no-crates TCP/wire ethos:
//!
//! 1. [`trace`] — RAII span guards over per-thread collectors, emitting a
//!    JSONL event log per run (`--trace PATH`) and accruing per-phase
//!    nanoseconds (compute / comms / stall / compress) into the
//!    [`trace::StepTiming`] breakdown that `TrainLog::write_csv` records
//!    per epoch. Spans are wired through the GEMM entry points, every
//!    `StepProtocol` round, the transports, Adam, and checkpoint I/O —
//!    comms spans carry the Ledger's `(tag, direction)` keys so bytes and
//!    seconds join on the same identity.
//! 2. [`metrics`] — an allocation-free registry of counters, gauges and a
//!    fixed-bucket step-latency histogram, rendered in Prometheus text
//!    format.
//! 3. [`serve`] — a `/metrics` endpoint over `std::net` exposed by
//!    `dad serve`, `dad join` and `dad infer --serve` (`--metrics ADDR`),
//!    plus [`summarize_trace`] behind `dad trace summarize PATH`.
//!
//! The metric-name inventory and trace-file schema are normative in
//! `docs/FORMATS.md` (§5) and drift-gated by `tests/format_spec.rs`.

pub mod metrics;
pub mod serve;
pub mod trace;

use std::io::{self, BufRead};
use std::path::Path;

/// Per-span-name aggregate used by the `dad trace summarize` table.
#[derive(Clone, Debug)]
pub struct SpanStat {
    /// Span name as recorded (e.g. `round-up`, `gemm-nn`).
    pub name: String,
    /// Phase attribution, if the span carried one.
    pub phase: String,
    /// Occurrence count.
    pub count: u64,
    /// Total duration across occurrences, seconds.
    pub total_s: f64,
    /// p50 duration, seconds.
    pub p50_s: f64,
    /// p99 duration, seconds.
    pub p99_s: f64,
}

/// Pull `"key":<integer>` out of a flat JSONL trace line.
fn json_u64(line: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Pull `"key":"value"` out of a flat JSONL trace line.
fn json_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":\"");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    Some(&rest[..rest.find('"')?])
}

/// Parse a JSONL trace written by [`trace::flush`] and aggregate per span
/// name: count, total, p50 and p99 durations, sorted by total descending.
/// Lines whose name starts with `_` (the footer) are skipped.
pub fn trace_stats(path: &Path) -> io::Result<Vec<SpanStat>> {
    let file = std::fs::File::open(path)?;
    // name → (phase, durations in ns)
    let mut by_name: Vec<(String, String, Vec<u64>)> = Vec::new();
    for line in io::BufReader::new(file).lines() {
        let line = line?;
        let Some(name) = json_str(&line, "name") else { continue };
        if name.starts_with('_') {
            continue;
        }
        let Some(dur) = json_u64(&line, "dur_ns") else { continue };
        let phase = json_str(&line, "phase").unwrap_or("-");
        match by_name.iter_mut().find(|(n, ..)| n == name) {
            Some((_, _, durs)) => durs.push(dur),
            None => by_name.push((name.to_string(), phase.to_string(), vec![dur])),
        }
    }
    let mut stats: Vec<SpanStat> = by_name
        .into_iter()
        .map(|(name, phase, mut durs)| {
            durs.sort_unstable();
            let total: u64 = durs.iter().sum();
            let pct = |q: f64| {
                let idx = ((q * durs.len() as f64).ceil() as usize).max(1) - 1;
                durs[idx.min(durs.len() - 1)] as f64 * 1e-9
            };
            SpanStat {
                name,
                phase,
                count: durs.len() as u64,
                total_s: total as f64 * 1e-9,
                p50_s: pct(0.50),
                p99_s: pct(0.99),
            }
        })
        .collect();
    stats.sort_by(|a, b| b.total_s.total_cmp(&a.total_s));
    Ok(stats)
}

/// Render the `dad trace summarize PATH` table: one row per span name
/// (sorted by total time), with a per-phase rollup footer.
pub fn summarize_trace(path: &Path) -> io::Result<String> {
    use std::fmt::Write as _;
    let stats = trace_stats(path)?;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<22} {:>9} {:>10} {:>12} {:>12} {:>12}",
        "span", "phase", "count", "total_s", "p50_s", "p99_s"
    );
    let mut phase_totals: Vec<(String, f64)> = Vec::new();
    for s in &stats {
        let _ = writeln!(
            out,
            "{:<22} {:>9} {:>10} {:>12.6} {:>12.6} {:>12.6}",
            s.name, s.phase, s.count, s.total_s, s.p50_s, s.p99_s
        );
        if s.phase != "-" {
            match phase_totals.iter_mut().find(|(p, _)| *p == s.phase) {
                Some((_, t)) => *t += s.total_s,
                None => phase_totals.push((s.phase.clone(), s.total_s)),
            }
        }
    }
    if !phase_totals.is_empty() {
        let _ = writeln!(out, "--");
        for (phase, total) in &phase_totals {
            let _ = writeln!(out, "{phase:<22} {total:>12.6} s");
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_aggregates_a_round_trip_trace() {
        let dir = std::env::temp_dir().join(format!("dad-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unit.jsonl");
        std::fs::write(
            &path,
            "{\"name\":\"round-up\",\"tag\":\"acts\",\"phase\":\"comms\",\"ts_ns\":0,\"dur_ns\":2000000,\"tid\":0,\"thread\":\"main\"}\n\
             {\"name\":\"round-up\",\"tag\":\"acts\",\"phase\":\"comms\",\"ts_ns\":9,\"dur_ns\":4000000,\"tid\":0,\"thread\":\"main\"}\n\
             {\"name\":\"gemm-nn\",\"ts_ns\":5,\"dur_ns\":1000000,\"tid\":1,\"thread\":\"dad-worker-0\"}\n\
             {\"name\":\"_meta\",\"dropped\":0}\n",
        )
        .unwrap();
        let stats = trace_stats(&path).unwrap();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].name, "round-up");
        assert_eq!(stats[0].count, 2);
        assert!((stats[0].total_s - 0.006).abs() < 1e-9);
        assert_eq!(stats[0].phase, "comms");
        assert_eq!(stats[1].name, "gemm-nn");
        assert_eq!(stats[1].phase, "-");
        let table = summarize_trace(&path).unwrap();
        assert!(table.contains("round-up"), "{table}");
        assert!(table.contains("comms"), "{table}");
        std::fs::remove_file(&path).ok();
    }
}

//! A minimal `/metrics` HTTP endpoint over `std::net` — the same
//! zero-dependency TCP stack the wire protocol uses. One listener thread
//! answers each connection with a single Prometheus text-format response
//! and closes; there is no keep-alive, no routing beyond `/metrics`, and
//! no request body handling, which is exactly enough for a scraper or a
//! `curl` in CI.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::metrics;

/// Handle to a running metrics listener; dropping it stops the thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9100`; port 0 picks a free port) and
    /// serve [`metrics::render`] on `GET /metrics` from a background
    /// thread until [`stop`](MetricsServer::stop) or drop.
    pub fn start(addr: &str) -> io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("dad-metrics".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        // A slow or stuck client must not wedge the
                        // listener: bound both directions.
                        let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
                        let _ = stream.set_write_timeout(Some(Duration::from_secs(2)));
                        let _ = answer(stream);
                    }
                }
            })?;
        Ok(MetricsServer { addr: local, stop, handle: Some(handle) })
    }

    /// The actual bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the listener thread and wait for it to exit.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Read the request head (best effort) and write one response.
fn answer(mut stream: TcpStream) -> io::Result<()> {
    let mut head = [0u8; 1024];
    let n = stream.read(&mut head).unwrap_or(0);
    let request_line = std::str::from_utf8(&head[..n])
        .ok()
        .and_then(|s| s.lines().next())
        .unwrap_or("");
    let path = request_line.split_whitespace().nth(1).unwrap_or("");
    if path == "/metrics" || path.starts_with("/metrics?") {
        let body = metrics::render();
        write!(
            stream,
            "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )?;
    } else {
        let body = "see /metrics\n";
        write!(
            stream,
            "HTTP/1.0 404 Not Found\r\nContent-Type: text/plain\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )?;
    }
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn http_get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_metrics_and_404s_elsewhere() {
        let mut srv = MetricsServer::start("127.0.0.1:0").unwrap();
        let res = http_get(srv.addr(), "/metrics");
        assert!(res.starts_with("HTTP/1.0 200 OK"), "bad status: {res}");
        assert!(res.contains("# TYPE dad_step gauge"), "missing exposition body: {res}");
        let res = http_get(srv.addr(), "/other");
        assert!(res.starts_with("HTTP/1.0 404"), "bad status: {res}");
        srv.stop();
    }
}

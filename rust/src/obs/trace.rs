//! Lightweight structured tracing: RAII span guards over per-thread event
//! buffers, plus thread-local phase accounting that survives even when the
//! JSONL event log is disabled.
//!
//! Design constraints (see `docs/OPERATIONS.md` §Observability):
//!
//! * **Allocation-free hot path.** A span records its name as a `&'static
//!   str`, copies an optional tag into an inline `[u8; 24]`, and on drop
//!   pushes a fixed-size [`Event`] into a per-thread `Vec` whose capacity
//!   was reserved when the thread recorded its first span (i.e. during
//!   warm-up). When the buffer fills, events are *dropped and counted* —
//!   never reallocated or flushed from the hot path. `tests/alloc_free.rs`
//!   arms a counting allocator around a traced steady-state step to keep
//!   this honest.
//! * **Thread-local phase buckets.** `scenario::runner` runs the aggregator
//!   and every simulated site in one process, so global accumulators would
//!   mix their timings. Each thread accrues nanoseconds into its own
//!   `[u64; 4]` (compute / comms / stall / compress); each training loop
//!   drains *its own* thread's buckets once per step via
//!   [`take_step_timing`]. Only the outermost phase-carrying span on a
//!   thread accrues, so nested spans (a GEMM inside `local_stats`) are not
//!   double counted.
//! * **Phases always accrue.** `Instant::now` is cheap, so the
//!   `StepTiming` CSV columns are populated even without `--trace PATH`;
//!   the JSONL event log is the opt-in part.

use std::cell::{Cell, RefCell};
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Inline tag capacity: the longest live wire tag (`infer-shutdown`, 14
/// bytes) fits with slack; longer tags are truncated, never allocated.
const TAG_CAP: usize = 24;

/// Per-thread event-buffer capacity, reserved up front on the thread's
/// first span so steady-state pushes never reallocate.
const BUF_CAP: usize = 1 << 16;

/// The wall-clock phase a span's duration is attributed to in the
/// per-step [`StepTiming`] breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Local math: forward/backward stats, optimizer update.
    Compute,
    /// Actively shipping bytes (serialize + socket write).
    Comms,
    /// Blocked waiting on a peer's frame (straggler / latency stall).
    Stall,
    /// Gradient compression: top-k selection, power iterations, encoding.
    Compress,
}

impl Phase {
    fn index(self) -> usize {
        match self {
            Phase::Compute => 0,
            Phase::Comms => 1,
            Phase::Stall => 2,
            Phase::Compress => 3,
        }
    }

    fn as_str(self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Comms => "comms",
            Phase::Stall => "stall",
            Phase::Compress => "compress",
        }
    }
}

/// Per-step (or per-epoch, when accumulated) wall-clock breakdown in
/// seconds, drained from the calling thread's phase buckets.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepTiming {
    /// Seconds spent in local math (stats, optimizer).
    pub compute_s: f64,
    /// Seconds spent actively shipping bytes.
    pub comms_s: f64,
    /// Seconds spent blocked on a peer's frame.
    pub stall_s: f64,
    /// Seconds spent compressing gradients.
    pub compress_s: f64,
}

impl StepTiming {
    /// Accumulate another breakdown into this one (per-step → per-epoch).
    pub fn accumulate(&mut self, other: &StepTiming) {
        self.compute_s += other.compute_s;
        self.comms_s += other.comms_s;
        self.stall_s += other.stall_s;
        self.compress_s += other.compress_s;
    }

    /// Total attributed seconds across all four phases.
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comms_s + self.stall_s + self.compress_s
    }
}

/// One recorded span occurrence. Fixed-size so the per-thread buffer is a
/// flat `Vec` with no per-event allocation.
#[derive(Clone, Copy)]
struct Event {
    name: &'static str,
    tag: [u8; TAG_CAP],
    tag_len: u8,
    phase: Option<Phase>,
    start_ns: u64,
    dur_ns: u64,
}

/// A thread's registered event buffer. The mutex is uncontended on the
/// hot path (only `flush` takes it from another thread).
struct ThreadBuf {
    tid: u32,
    name: String,
    events: Mutex<Vec<Event>>,
    dropped: AtomicU64,
}

static REGISTRY: Mutex<Vec<Arc<ThreadBuf>>> = Mutex::new(Vec::new());
static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<BufWriter<File>>> = Mutex::new(None);

/// Process-relative time origin for all span timestamps.
fn origin() -> Instant {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    *ORIGIN.get_or_init(Instant::now)
}

thread_local! {
    static TBUF: RefCell<Option<Arc<ThreadBuf>>> = const { RefCell::new(None) };
    static PHASE_NS: Cell<[u64; 4]> = const { Cell::new([0; 4]) };
    static PHASE_DEPTH: Cell<u32> = const { Cell::new(0) };
}

/// Run `f` with this thread's buffer, creating and registering it on
/// first use (an allocation, which is why warm-up iterations must record
/// at least one span before an allocation-sensitive region is armed).
fn with_thread_buf(f: impl FnOnce(&ThreadBuf)) {
    TBUF.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let mut reg = REGISTRY.lock().unwrap();
            let buf = Arc::new(ThreadBuf {
                tid: reg.len() as u32,
                name: std::thread::current().name().unwrap_or("?").to_string(),
                events: Mutex::new(Vec::with_capacity(BUF_CAP)),
                dropped: AtomicU64::new(0),
            });
            reg.push(Arc::clone(&buf));
            *slot = Some(buf);
        }
        f(slot.as_ref().unwrap());
    });
}

/// RAII span guard: construct at the top of the region, measurement ends
/// when the guard drops. Phase-carrying spans additionally accrue their
/// duration into the thread's [`StepTiming`] buckets (outermost only).
pub struct Span {
    start: Instant,
    name: &'static str,
    tag: [u8; TAG_CAP],
    tag_len: u8,
    phase: Option<Phase>,
    accrue: bool,
}

impl Span {
    fn begin(name: &'static str, tag: &str, phase: Option<Phase>) -> Span {
        let mut accrue = false;
        if phase.is_some() {
            let d = PHASE_DEPTH.with(|c| {
                let d = c.get();
                c.set(d + 1);
                d
            });
            accrue = d == 0;
        }
        let mut buf = [0u8; TAG_CAP];
        let n = tag.len().min(TAG_CAP);
        buf[..n].copy_from_slice(&tag.as_bytes()[..n]);
        Span { start: Instant::now(), name, tag: buf, tag_len: n as u8, phase, accrue }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let dur_ns = self.start.elapsed().as_nanos() as u64;
        if let Some(p) = self.phase {
            PHASE_DEPTH.with(|c| c.set(c.get() - 1));
            if self.accrue {
                PHASE_NS.with(|c| {
                    let mut ns = c.get();
                    ns[p.index()] += dur_ns;
                    c.set(ns);
                });
            }
        }
        if ENABLED.load(Ordering::Relaxed) {
            let start_ns = self.start.duration_since(origin()).as_nanos() as u64;
            let ev = Event {
                name: self.name,
                tag: self.tag,
                tag_len: self.tag_len,
                phase: self.phase,
                start_ns,
                dur_ns,
            };
            with_thread_buf(|buf| {
                let mut events = buf.events.lock().unwrap();
                if events.len() < events.capacity() {
                    events.push(ev);
                } else {
                    buf.dropped.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    }
}

/// Open an untagged, phase-less span (pure trace detail, e.g. a GEMM).
pub fn span(name: &'static str) -> Span {
    Span::begin(name, "", None)
}

/// Open a span whose duration accrues into `phase`'s bucket.
pub fn phase_span(name: &'static str, phase: Phase) -> Span {
    Span::begin(name, "", Some(phase))
}

/// Open a phase span tagged with a wire/ledger key, so bytes (Ledger) and
/// seconds (trace) join on the same `(tag, direction)` identity.
pub fn tagged_span(name: &'static str, tag: &str, phase: Phase) -> Span {
    Span::begin(name, tag, Some(phase))
}

/// Drain and reset the *calling thread's* phase buckets. Each training
/// loop calls this once per step on its own thread; in-process site
/// threads and the aggregator therefore never mix.
pub fn take_step_timing() -> StepTiming {
    let ns = PHASE_NS.with(|c| c.replace([0; 4]));
    StepTiming {
        compute_s: ns[0] as f64 * 1e-9,
        comms_s: ns[1] as f64 * 1e-9,
        stall_s: ns[2] as f64 * 1e-9,
        compress_s: ns[3] as f64 * 1e-9,
    }
}

/// Begin writing a JSONL trace to `path` and start collecting span
/// events. Until this is called, spans cost two `Instant::now` reads and
/// a phase-bucket add; no buffers exist and nothing is retained.
pub fn enable(path: &Path) -> io::Result<()> {
    origin(); // pin the time origin before any event is recorded
    let file = File::create(path)?;
    *SINK.lock().unwrap() = Some(BufWriter::new(file));
    ENABLED.store(true, Ordering::SeqCst);
    Ok(())
}

/// True when a JSONL sink is active (spans are being retained).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Drain every registered thread buffer into the JSONL sink. Formatting
/// allocates freely — call this at epoch boundaries or run end, never
/// from an allocation-sensitive region.
pub fn flush() -> io::Result<()> {
    let mut sink = SINK.lock().unwrap();
    let Some(out) = sink.as_mut() else { return Ok(()) };
    let bufs: Vec<Arc<ThreadBuf>> = REGISTRY.lock().unwrap().clone();
    let mut line = String::with_capacity(160);
    for buf in bufs {
        let mut events = buf.events.lock().unwrap();
        for ev in events.drain(..) {
            line.clear();
            line.push_str("{\"name\":\"");
            line.push_str(ev.name);
            line.push('"');
            if ev.tag_len > 0 {
                line.push_str(",\"tag\":\"");
                line.push_str(std::str::from_utf8(&ev.tag[..ev.tag_len as usize]).unwrap_or("?"));
                line.push('"');
            }
            if let Some(p) = ev.phase {
                line.push_str(",\"phase\":\"");
                line.push_str(p.as_str());
                line.push('"');
            }
            use std::fmt::Write as _;
            let _ = write!(
                line,
                ",\"ts_ns\":{},\"dur_ns\":{},\"tid\":{},\"thread\":\"{}\"}}",
                ev.start_ns, ev.dur_ns, buf.tid, buf.name
            );
            writeln!(out, "{line}")?;
        }
    }
    out.flush()
}

/// Flush remaining events, append a `_meta` footer line (dropped-event
/// census), close the sink, and stop retaining spans.
pub fn finish() -> io::Result<()> {
    flush()?;
    ENABLED.store(false, Ordering::SeqCst);
    let mut sink = SINK.lock().unwrap();
    if let Some(mut out) = sink.take() {
        let dropped: u64 = REGISTRY
            .lock()
            .unwrap()
            .iter()
            .map(|b| b.dropped.load(Ordering::Relaxed))
            .sum();
        writeln!(out, "{{\"name\":\"_meta\",\"dropped\":{dropped}}}")?;
        out.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outermost_phase_span_accrues_once() {
        let _ = take_step_timing(); // reset this thread
        {
            let _outer = phase_span("outer", Phase::Compute);
            let _inner = phase_span("inner", Phase::Comms);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let t = take_step_timing();
        assert!(t.compute_s >= 0.004, "outer span did not accrue: {t:?}");
        assert_eq!(t.comms_s, 0.0, "nested span double-counted: {t:?}");
        // Buckets reset after the take.
        assert_eq!(take_step_timing(), StepTiming::default());
    }

    #[test]
    fn phaseless_spans_do_not_touch_buckets() {
        let _ = take_step_timing();
        {
            let _g = span("gemm");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(take_step_timing(), StepTiming::default());
    }

    #[test]
    fn sibling_threads_keep_separate_buckets() {
        let handle = std::thread::spawn(|| {
            let _g = phase_span("peer", Phase::Stall);
            std::thread::sleep(std::time::Duration::from_millis(5));
            drop(_g);
            take_step_timing()
        });
        let _ = take_step_timing();
        let theirs = handle.join().unwrap();
        assert!(theirs.stall_s >= 0.004);
        let mine = take_step_timing();
        assert_eq!(mine.stall_s, 0.0, "another thread's stall leaked into mine");
    }
}

//! Compute backends for the MLP local-stats step: native (the from-scratch
//! tensor engine) or PJRT (the AOT-compiled JAX+Pallas artifact). Both
//! produce the same (loss, A-stacks, Δ-stacks) — asserted by the
//! integration test — so the coordinator can run the paper's hot path on
//! compiled XLA code with Python nowhere in sight.
//!
//! Error handling is the crate-local `runtime::Result` so this module (and
//! everything that selects a backend) builds with or without the `pjrt`
//! feature; the real client's anyhow errors are flattened at the boundary.

use super::pjrt::{PjrtInput, PjrtRuntime};
use super::{Result, RuntimeError};
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::LocalStats;
use crate::nn::Mlp;
use crate::tensor::Matrix;

/// The canonical artifact batch size (python/compile/aot.py): 32 per site.
pub const ARTIFACT_BATCH: usize = 32;
/// The canonical artifact layer dims: 784-1024-1024-10.
pub const ARTIFACT_DIMS: [usize; 4] = [784, 1024, 1024, 10];

/// A provider of MLP local statistics.
pub trait MlpBackend {
    /// Backend name for diagnostics ("native", "pjrt").
    fn name(&self) -> &'static str;
    /// (loss, stats) for one site batch.
    fn local_stats(&mut self, mlp: &Mlp, batch: &Batch) -> Result<LocalStats>;
}

/// Native backend: the pure-Rust reverse-AD tape.
pub struct NativeMlpBackend;

impl MlpBackend for NativeMlpBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn local_stats(&mut self, mlp: &Mlp, batch: &Batch) -> Result<LocalStats> {
        Ok(mlp.local_stats(batch))
    }
}

/// PJRT backend: executes artifacts/mlp_stats.hlo.txt. Fixed to the
/// artifact's traced shapes (the AOT contract); the native backend covers
/// every other configuration. Without the `pjrt` feature the underlying
/// runtime is the stub and construction fails cleanly.
pub struct PjrtMlpBackend {
    runtime: PjrtRuntime,
}

impl PjrtMlpBackend {
    /// Wrap an already-initialized runtime.
    pub fn new(runtime: PjrtRuntime) -> Self {
        PjrtMlpBackend { runtime }
    }

    /// Initialize from the default artifact directory (DAD_ARTIFACTS).
    pub fn from_default_artifacts() -> Result<Self> {
        let runtime = PjrtRuntime::cpu(PjrtRuntime::default_dir())
            .map_err(|e| RuntimeError(format!("{e:#}")))?;
        Ok(PjrtMlpBackend { runtime })
    }

    fn check_shapes(mlp: &Mlp, batch: &Batch) -> Result<(Matrix, Matrix)> {
        let (x, y) = match batch {
            Batch::Dense { x, y } => (x.clone(), y.clone()),
            _ => return Err(RuntimeError::msg("PJRT MLP backend consumes dense batches")),
        };
        if mlp.dims != ARTIFACT_DIMS.to_vec() {
            return Err(RuntimeError(format!(
                "artifact is traced for dims {ARTIFACT_DIMS:?}, model has {:?}",
                mlp.dims
            )));
        }
        if x.rows() != ARTIFACT_BATCH {
            return Err(RuntimeError(format!(
                "artifact is traced for batch {ARTIFACT_BATCH}, got {}",
                x.rows()
            )));
        }
        Ok((x, y))
    }
}

impl MlpBackend for PjrtMlpBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn local_stats(&mut self, mlp: &Mlp, batch: &Batch) -> Result<LocalStats> {
        let (x, y) = Self::check_shapes(mlp, batch)?;
        // Artifact signature (aot.py): (w1,b1,w2,b2,w3,b3,x,y) ->
        // (loss, a0, a1, a2, d1, d2, d3).
        let params = mlp.params();
        let mut inputs: Vec<PjrtInput> = Vec::with_capacity(8);
        for layer in 0..3 {
            inputs.push(PjrtInput::from_matrix(params[2 * layer]));
            inputs.push(PjrtInput::from_row(params[2 * layer + 1].row(0)));
        }
        inputs.push(PjrtInput::from_matrix(&x));
        inputs.push(PjrtInput::from_matrix(&y));
        let out = self
            .runtime
            .execute("mlp_stats", &inputs)
            .map_err(|e| RuntimeError(format!("{e:#}")))?;
        if out.len() != 7 {
            return Err(RuntimeError(format!(
                "mlp_stats artifact returned {} outputs, expected 7",
                out.len()
            )));
        }
        let loss = out[0].scalar();
        let a = [out[1].to_matrix(), out[2].to_matrix(), out[3].to_matrix()];
        let d = [out[4].to_matrix(), out[5].to_matrix(), out[6].to_matrix()];
        let entries = (0..3)
            .map(|i| crate::nn::stats::StatsEntry {
                w_idx: 2 * i,
                b_idx: Some(2 * i + 1),
                a: a[i].clone(),
                d: d[i].clone(),
            })
            .collect();
        Ok(LocalStats { loss, entries, aux: vec![], direct: vec![] })
    }
}

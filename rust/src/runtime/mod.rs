//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (python/compile/aot.py lowers the JAX+Pallas Layer-1/2 functions to HLO
//! text) and executes them on the XLA CPU client from the Rust hot path.
//!
//! Python never runs at training time: this module is the only bridge, and
//! its inputs are files. HLO *text* is the interchange format because the
//! vendored xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction-id
//! protos (see /opt/xla-example/README.md).

pub mod backend;
pub mod pjrt;

pub use backend::{MlpBackend, NativeMlpBackend, PjrtMlpBackend};
pub use pjrt::PjrtRuntime;

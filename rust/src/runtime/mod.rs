//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (python/compile/aot.py lowers the JAX+Pallas Layer-1/2 functions to HLO
//! text) and executes them on the XLA CPU client from the Rust hot path.
//!
//! Python never runs at training time: this module is the only bridge, and
//! its inputs are files. HLO *text* is the interchange format because the
//! vendored xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction-id
//! protos (see /opt/xla-example/README.md).
//!
//! ## Feature gating
//!
//! The real client lives in `pjrt.rs` and needs the vendored `xla` crate
//! (plus `anyhow`), which the offline build environment does not ship.
//! It compiles only under `--features pjrt` (add the vendored crates as
//! path dependencies first). By default `pjrt_stub.rs` provides the same
//! API surface — `PjrtRuntime::cpu` returns an error, every consumer falls
//! back to the native engine — so the crate, examples and CLI build with
//! zero external dependencies.

pub mod backend;

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub;
#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub as pjrt;

pub use backend::{MlpBackend, NativeMlpBackend, PjrtMlpBackend};
pub use pjrt::PjrtRuntime;

/// Minimal runtime-layer error (anyhow exists only behind the `pjrt`
/// feature, and the public API must not depend on it).
#[derive(Debug)]
pub struct RuntimeError(pub String);

impl RuntimeError {
    /// Build from any stringy message.
    pub fn msg(s: impl Into<String>) -> Self {
        RuntimeError(s.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Crate-local result alias for runtime-layer fallibility.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Default artifact location (repo-root relative), overridable with
/// DAD_ARTIFACTS. Lives here so both the real and stub runtimes share it.
pub(crate) fn default_artifacts_dir() -> std::path::PathBuf {
    use std::path::PathBuf;
    std::env::var("DAD_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        // Walk up from cwd looking for artifacts/.
        let mut d = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
        loop {
            let cand = d.join("artifacts");
            if cand.is_dir() {
                return cand;
            }
            if !d.pop() {
                return PathBuf::from("artifacts");
            }
        }
    })
}

//! Thin wrapper over the `xla` crate's PJRT CPU client: artifact loading,
//! executable caching, and Matrix <-> Literal conversion.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::tensor::Matrix;

/// A PJRT client plus a cache of compiled executables, keyed by artifact
/// name (e.g. "mlp_stats" -> artifacts/mlp_stats.hlo.txt).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// CPU client over the given artifacts directory.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime {
            client,
            dir: artifacts_dir.as_ref().to_path_buf(),
            cache: HashMap::new(),
        })
    }

    /// Default artifact location (repo-root relative), overridable with
    /// DAD_ARTIFACTS.
    pub fn default_dir() -> PathBuf {
        super::default_artifacts_dir()
    }

    /// PJRT platform name reported by the client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?} (run `make artifacts`)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Whether `name` is already compiled into the cache.
    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    /// Execute a loaded artifact on f32 inputs; returns the flattened tuple
    /// of f32 outputs as (shape, data) pairs.
    pub fn execute(&mut self, name: &str, inputs: &[PjrtInput]) -> Result<Vec<PjrtOutput>> {
        self.load(name)?;
        let exe = self.cache.get(name).unwrap();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|i| -> Result<xla::Literal> {
                let lit = xla::Literal::vec1(&i.data);
                let dims: Vec<usize> = i.dims.clone();
                if dims.len() == 1 && dims[0] == i.data.len() {
                    Ok(lit)
                } else if dims.is_empty() {
                    lit.reshape(&[]).context("scalar reshape")
                } else {
                    let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                    lit.reshape(&d).context("input reshape")
                }
            })
            .collect::<Result<_>>()?;
        let mut result = exe.execute::<xla::Literal>(&literals).context("execute")?[0][0]
            .to_literal_sync()
            .context("to_literal_sync")?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let elems = result.decompose_tuple().context("decompose_tuple")?;
        elems
            .into_iter()
            .map(|lit| -> Result<PjrtOutput> {
                let shape = lit.array_shape().context("array_shape")?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<f32>().context("to_vec<f32>")?;
                Ok(PjrtOutput { dims, data })
            })
            .collect()
    }
}

/// An f32 input tensor (row-major).
pub struct PjrtInput {
    /// Tensor shape (empty = scalar).
    pub dims: Vec<usize>,
    /// Row-major values.
    pub data: Vec<f32>,
}

impl PjrtInput {
    /// Rank-2 input from a matrix.
    pub fn from_matrix(m: &Matrix) -> Self {
        PjrtInput { dims: vec![m.rows(), m.cols()], data: m.data().to_vec() }
    }

    /// Rank-1 input from a slice.
    pub fn from_row(v: &[f32]) -> Self {
        PjrtInput { dims: vec![v.len()], data: v.to_vec() }
    }

    /// Rank-0 (scalar) input.
    pub fn scalar(v: f32) -> Self {
        PjrtInput { dims: vec![], data: vec![v] }
    }
}

/// An f32 output tensor (row-major).
#[derive(Debug, Clone)]
pub struct PjrtOutput {
    /// Tensor shape (empty = scalar).
    pub dims: Vec<usize>,
    /// Row-major values.
    pub data: Vec<f32>,
}

impl PjrtOutput {
    /// View as a matrix (rank <= 2; rank-1 becomes a row vector).
    pub fn to_matrix(&self) -> Matrix {
        match self.dims.len() {
            2 => Matrix::from_vec(self.dims[0], self.dims[1], self.data.clone()),
            1 => Matrix::from_vec(1, self.dims[0], self.data.clone()),
            0 => Matrix::from_vec(1, 1, self.data.clone()),
            _ => panic!("unsupported output rank {:?}", self.dims),
        }
    }

    /// The single value of a rank-0 output.
    pub fn scalar(&self) -> f32 {
        self.data[0]
    }
}

// NOTE: runtime tests live in rust/tests/pjrt_integration.rs (they need the
// artifacts built and the xla shared library available).

//! API-compatible stand-in for `pjrt.rs` when the crate is built without
//! the `pjrt` feature (no vendored xla available). `cpu()` always errors,
//! so every consumer (backend selection, CLI `info`, examples) takes its
//! native-engine fallback path; the input/output value types are fully
//! functional so shared code type-checks identically.

use std::path::{Path, PathBuf};

use super::{Result, RuntimeError};
use crate::tensor::Matrix;

fn unavailable<T>() -> Result<T> {
    Err(RuntimeError::msg(
        "built without the `pjrt` feature: vendored xla is unavailable; \
         rebuild with --features pjrt (see src/runtime/mod.rs)",
    ))
}

/// Stub PJRT client. Construction always fails; methods exist so callers
/// compile unchanged.
pub struct PjrtRuntime {
    _priv: (),
}

impl PjrtRuntime {
    /// Always errors in the stub build.
    pub fn cpu(_artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        unavailable()
    }

    /// Default artifact location (repo-root relative), overridable with
    /// DAD_ARTIFACTS.
    pub fn default_dir() -> PathBuf {
        super::default_artifacts_dir()
    }

    /// Platform name ("stub").
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Always errors in the stub build.
    pub fn load(&mut self, _name: &str) -> Result<()> {
        unavailable()
    }

    /// Always false in the stub build.
    pub fn is_loaded(&self, _name: &str) -> bool {
        false
    }

    /// Always errors in the stub build.
    pub fn execute(&mut self, _name: &str, _inputs: &[PjrtInput]) -> Result<Vec<PjrtOutput>> {
        unavailable()
    }
}

/// An f32 input tensor (row-major).
pub struct PjrtInput {
    /// Tensor shape (empty = scalar).
    pub dims: Vec<usize>,
    /// Row-major values.
    pub data: Vec<f32>,
}

impl PjrtInput {
    /// Rank-2 input from a matrix.
    pub fn from_matrix(m: &Matrix) -> Self {
        PjrtInput { dims: vec![m.rows(), m.cols()], data: m.data().to_vec() }
    }

    /// Rank-1 input from a slice.
    pub fn from_row(v: &[f32]) -> Self {
        PjrtInput { dims: vec![v.len()], data: v.to_vec() }
    }

    /// Rank-0 (scalar) input.
    pub fn scalar(v: f32) -> Self {
        PjrtInput { dims: vec![], data: vec![v] }
    }
}

/// An f32 output tensor (row-major).
#[derive(Debug, Clone)]
pub struct PjrtOutput {
    /// Tensor shape (empty = scalar).
    pub dims: Vec<usize>,
    /// Row-major values.
    pub data: Vec<f32>,
}

impl PjrtOutput {
    /// View as a matrix (rank <= 2; rank-1 becomes a row vector).
    pub fn to_matrix(&self) -> Matrix {
        match self.dims.len() {
            2 => Matrix::from_vec(self.dims[0], self.dims[1], self.data.clone()),
            1 => Matrix::from_vec(1, self.dims[0], self.data.clone()),
            0 => Matrix::from_vec(1, 1, self.data.clone()),
            _ => panic!("unsupported output rank {:?}", self.dims),
        }
    }

    /// The single value of a rank-0 output.
    pub fn scalar(&self) -> f32 {
        self.data[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_reports_feature_gate() {
        let err = PjrtRuntime::cpu("artifacts").err().expect("stub must error");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn value_types_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let inp = PjrtInput::from_matrix(&m);
        assert_eq!(inp.dims, vec![2, 3]);
        let out = PjrtOutput { dims: vec![2, 3], data: inp.data.clone() };
        assert_eq!(out.to_matrix(), m);
        assert_eq!(PjrtInput::scalar(4.5).data, vec![4.5]);
        assert_eq!(PjrtInput::from_row(&[1.0, 2.0]).dims, vec![2]);
    }
}

//! Declarative chaos recipes: named fault/heterogeneity scenarios that
//! compose a training spec (algorithm × sync schedule × site count), a
//! partition override (`crate::data::Partition`), per-site
//! [`ChaosSpec`]s, and an expected outcome — runnable as
//! `dad chaos --recipe <name>` and asserted end-to-end by
//! `tests/chaos_recipes.rs`.
//!
//! A recipe's contract is **convergence or clean failure**: the run either
//! completes with metrics (possibly degraded to the surviving sites — see
//! `coordinator::remote`'s fault policy) or returns a clean `io::Error`
//! whose message names the cause. Never a hang, never a panic. The
//! [`Expectation`] encodes which of the three outcomes the recipe is
//! *supposed* to produce:
//!
//! | expectation        | meaning                                          |
//! |--------------------|--------------------------------------------------|
//! | `converge`         | completes with every site still alive            |
//! | `degrade:<k>`      | completes with exactly `k` surviving sites       |
//! | `fail:<substring>` | returns an error whose message contains the text |
//!
//! Recipes are deterministic: chaos schedules are seeded pure functions
//! (`dist::transport::chaos`), batch schedules replay from the run seed,
//! and step-gated disconnects land on step boundaries — so two runs of
//! the same recipe produce the same losses, the same ledger bytes and the
//! same survivor trajectory. Custom recipes load from TOML files
//! (`config::toml_lite` subset) with the same fields the named registry
//! uses; see `Recipe::from_toml`.

pub mod runner;

pub use runner::{run_recipe, RecipeReport};

use crate::algos::AlgoSpec;
use crate::config::TomlLite;
use crate::coordinator::{Schedule, TrainSpec};
use crate::data::Partition;
use crate::dist::{ChaosSpec, CostModel};

/// What a recipe is supposed to do — the assertion target for the CI
/// recipe matrix and `tests/chaos_recipes.rs`.
#[derive(Debug, Clone, PartialEq)]
pub enum Expectation {
    /// The run completes with every site alive.
    Converge,
    /// The run completes with exactly this many surviving sites in the
    /// final epoch's `sites_live`.
    Degrade(usize),
    /// The run fails cleanly with an error containing this substring.
    Fail(String),
}

impl Expectation {
    /// Parse the recipe-file spelling: `converge | degrade:<k> | fail:<text>`.
    pub fn parse(s: &str) -> Result<Expectation, String> {
        if s == "converge" {
            return Ok(Expectation::Converge);
        }
        if let Some(k) = s.strip_prefix("degrade:") {
            let k: usize = k.parse().map_err(|_| format!("bad survivor count in {s:?}"))?;
            return Ok(Expectation::Degrade(k));
        }
        if let Some(text) = s.strip_prefix("fail:") {
            return Ok(Expectation::Fail(text.to_string()));
        }
        Err(format!("unknown expectation {s:?} (converge | degrade:<k> | fail:<substring>)"))
    }

    /// The canonical spelling [`Expectation::parse`] round-trips.
    pub fn name(&self) -> String {
        match self {
            Expectation::Converge => "converge".into(),
            Expectation::Degrade(k) => format!("degrade:{k}"),
            Expectation::Fail(text) => format!("fail:{text}"),
        }
    }
}

/// One named chaos scenario: everything needed to reproduce a fault run.
#[derive(Debug, Clone)]
pub struct Recipe {
    /// Recipe name (`dad chaos --recipe <name>`).
    pub name: String,
    /// One-line description for `--list` and the README recipe table.
    pub summary: String,
    /// Training spec (algorithm, sites, schedule, seed, ...).
    pub spec: TrainSpec,
    /// Dataset name (`trainer::build_task`).
    pub dataset: String,
    /// Scale preset string (recipes default to "quick").
    pub scale: String,
    /// Partition override applied identically in every process.
    pub partition: Partition,
    /// Per-site fault schedule, indexed by site id (missing = quiet).
    pub site_chaos: Vec<ChaosSpec>,
    /// Fail the run on the first lost site instead of degrading
    /// (overridable from the CLI with `--strict`).
    pub strict: bool,
    /// Aggregator per-frame recv deadline (straggler detection), ms;
    /// 0 disarms it.
    pub straggler_deadline_ms: u64,
    /// Handshake deadline for `accept_sites`, ms.
    pub handshake_timeout_ms: u64,
    /// Site-side per-frame recv deadline (shipped in the config frame), ms.
    pub recv_timeout_ms: u32,
    /// Interior fan-out: 0 runs the classic flat star; `R > 0` interposes
    /// `R` relay threads between the aggregator and the sites, splitting
    /// the sites into `R` contiguous subtrees (the `dad relay` topology,
    /// compressed into one process).
    pub tree_links: usize,
    /// The outcome this recipe is supposed to produce.
    pub expect: Expectation,
}

impl Recipe {
    /// A quiet baseline recipe every scenario starts from: 3 sites on
    /// quick-scale mnist, 2 epochs, every-batch sync, generous deadlines.
    fn base(name: &str, summary: &str, algo: AlgoSpec) -> Recipe {
        Recipe {
            name: name.to_string(),
            summary: summary.to_string(),
            spec: TrainSpec {
                algo,
                n_sites: 3,
                batch_per_site: 16,
                epochs: 2,
                lr: 1e-4,
                seed: 13,
                schedule: Schedule::EveryBatch,
            },
            dataset: "mnist".into(),
            scale: "quick".into(),
            partition: Partition::Default,
            site_chaos: vec![],
            strict: false,
            straggler_deadline_ms: 30_000,
            handshake_timeout_ms: 30_000,
            recv_timeout_ms: 60_000,
            tree_links: 0,
            expect: Expectation::Converge,
        }
    }

    /// The chaos spec for `site` (quiet when the recipe leaves it unset).
    pub fn chaos_for(&self, site: usize) -> ChaosSpec {
        self.site_chaos.get(site).copied().unwrap_or_default()
    }

    /// Parse a recipe from TOML text. Layout (all keys optional except
    /// `name`; defaults mirror the named-recipe baseline):
    ///
    /// ```toml
    /// name = "my-scenario"
    /// summary = "what it stresses"
    /// expect = "degrade:2"          # converge | degrade:<k> | fail:<text>
    /// strict = false
    /// straggler_deadline_ms = 2000
    /// handshake_timeout_ms = 30000
    /// recv_timeout_ms = 60000
    ///
    /// [train]
    /// algo = "dad"                  # any AlgoSpec spelling
    /// dataset = "mnist"
    /// sites = 3
    /// batch = 16
    /// epochs = 2
    /// lr = 1e-4
    /// seed = 13
    /// sync_every = 1
    /// partition = "default"         # default | iid | skew:<ratio>
    /// tree_links = 0                # 0 = flat star; R = sites behind R relays
    ///
    /// [chaos.site.1]                # one section per faulty site
    /// seed = 7
    /// link = "wan"                  # lan | wan | dsl | sat
    /// jitter_ms = 5
    /// drop_every = 0
    /// disconnect_after_frames = 0
    /// disconnect_at_step = 3
    /// stall_at_step = 0
    /// stall_ms = 0
    /// ```
    pub fn from_toml(text: &str) -> Result<Recipe, String> {
        let cfg = TomlLite::parse(text)?;
        let name = cfg.str_or("", "name", "");
        if name.is_empty() {
            return Err("recipe file needs a root-level name = \"...\"".into());
        }
        let algo_s = cfg.str_or("train", "algo", "dad").to_string();
        let algo = AlgoSpec::parse(&algo_s).map_err(|e| format!("train.algo: {e}"))?;
        let mut r = Recipe::base(name, cfg.str_or("", "summary", "custom recipe"), algo);
        r.dataset = cfg.str_or("train", "dataset", "mnist").to_string();
        r.spec.n_sites = cfg.int_or("train", "sites", 3) as usize;
        r.spec.batch_per_site = cfg.int_or("train", "batch", 16) as usize;
        r.spec.epochs = cfg.int_or("train", "epochs", 2) as usize;
        r.spec.lr = cfg.float_or("train", "lr", 1e-4) as f32;
        r.spec.seed = cfg.int_or("train", "seed", 13) as u64;
        r.spec.schedule = Schedule::from_sync_every(cfg.int_or("train", "sync_every", 1) as usize);
        r.partition = Partition::parse(cfg.str_or("train", "partition", "default"))
            .map_err(|e| format!("train.partition: {e}"))?;
        r.tree_links = cfg.int_or("train", "tree_links", 0) as usize;
        r.strict = cfg.bool_or("", "strict", false);
        r.straggler_deadline_ms = cfg.int_or("", "straggler_deadline_ms", 30_000) as u64;
        r.handshake_timeout_ms = cfg.int_or("", "handshake_timeout_ms", 30_000) as u64;
        r.recv_timeout_ms = cfg.int_or("", "recv_timeout_ms", 60_000) as u32;
        r.expect = Expectation::parse(cfg.str_or("", "expect", "converge"))?;
        let mut site_chaos = vec![ChaosSpec::default(); r.spec.n_sites];
        for (site, chaos) in site_chaos.iter_mut().enumerate() {
            let sec = format!("chaos.site.{site}");
            if !cfg.sections.contains_key(&sec) {
                continue;
            }
            chaos.seed = cfg.int_or(&sec, "seed", 0) as u64;
            let link = cfg.str_or(&sec, "link", "");
            if !link.is_empty() {
                chaos.link_cost =
                    Some(CostModel::parse(link).map_err(|e| format!("{sec}.link: {e}"))?);
            }
            chaos.jitter_s = cfg.float_or(&sec, "jitter_ms", 0.0) * 1e-3;
            chaos.drop_every = cfg.int_or(&sec, "drop_every", 0) as usize;
            chaos.disconnect_after_frames = cfg.int_or(&sec, "disconnect_after_frames", 0) as usize;
            chaos.disconnect_at_step = cfg.int_or(&sec, "disconnect_at_step", 0) as usize;
            chaos.stall_at_step = cfg.int_or(&sec, "stall_at_step", 0) as usize;
            chaos.stall_s = cfg.float_or(&sec, "stall_ms", 0.0) * 1e-3;
        }
        r.site_chaos = site_chaos;
        Ok(r)
    }
}

/// A site that dies at training step 3 of an otherwise quiet 3-site run.
fn mid_drop(name: &str, algo: AlgoSpec, algo_label: &str) -> Recipe {
    let mut r = Recipe::base(
        name,
        &format!("site 2 disconnects at step 3; {algo_label} continues with 2 survivors"),
        algo,
    );
    let mut chaos = vec![ChaosSpec::default(); 3];
    chaos[2] = ChaosSpec { seed: 23, disconnect_at_step: 3, ..ChaosSpec::default() };
    r.site_chaos = chaos;
    r.straggler_deadline_ms = 5_000;
    r.expect = Expectation::Degrade(2);
    r
}

/// The named recipe registry — every scenario the CI recipe matrix runs.
pub fn named_recipes() -> Vec<Recipe> {
    let mut recipes = vec![];

    recipes.push(Recipe::base(
        "clean-dad",
        "fault-free 3-site dAD baseline; the matrix's control group",
        AlgoSpec::Dad,
    ));

    let mut r = Recipe::base(
        "slow-link-dad",
        "every site behind a jittery WAN link; pure delay must not change the math",
        AlgoSpec::Dad,
    );
    r.site_chaos = (0..3)
        .map(|s| {
            let mut c = ChaosSpec::delay_only(40 + s, CostModel::wan_federated(), 0.002);
            // Scale the deterministic base cost down so a quick-scale CI
            // run stays fast while every frame still pays a nonzero delay.
            c.link_cost = Some(CostModel::custom(1e-4, 1e9));
            c
        })
        .collect();
    recipes.push(r);

    let mut r = Recipe::base(
        "slow-link-rank-dad",
        "rank-dAD over congested uplinks: compression earns its keep on slow links",
        AlgoSpec::RankDad { max_rank: 4, n_iters: 10, theta: 1e-3 },
    );
    r.site_chaos = (0..3)
        .map(|s| ChaosSpec::delay_only(50 + s, CostModel::custom(1e-4, 1e9), 0.001))
        .collect();
    recipes.push(r);

    recipes.push(mid_drop("mid-drop-dad", AlgoSpec::Dad, "dAD"));
    recipes.push(mid_drop("mid-drop-dsgd", AlgoSpec::Dsgd, "dSGD"));
    recipes.push(mid_drop(
        "mid-drop-rank-dad",
        AlgoSpec::RankDad { max_rank: 2, n_iters: 10, theta: 1e-3 },
        "rank-dAD",
    ));
    // Residual-carrying sparse protocol: the dead site's error-feedback
    // state dies with it; the survivors' residuals are per-site, so the
    // protocol degrades rather than refusing.
    recipes.push(mid_drop("dgc-mid-drop", AlgoSpec::Dgc { density: 25.0 }, "DGC"));

    let mut r = Recipe::base(
        "tree-churn-dad",
        "4 sites behind 2 relays; site 3 dies at step 3 and the whole tree degrades to 3",
        AlgoSpec::Dad,
    );
    r.spec.n_sites = 4;
    r.tree_links = 2;
    let mut chaos = vec![ChaosSpec::default(); 4];
    chaos[3] = ChaosSpec { seed: 23, disconnect_at_step: 3, ..ChaosSpec::default() };
    r.site_chaos = chaos;
    r.straggler_deadline_ms = 5_000;
    r.expect = Expectation::Degrade(3);
    recipes.push(r);

    let mut r = Recipe::base(
        "straggler-dad",
        "site 1 stalls past the straggler deadline at step 2 and is retired",
        AlgoSpec::Dad,
    );
    let mut chaos = vec![ChaosSpec::default(); 3];
    chaos[1] = ChaosSpec { seed: 31, stall_at_step: 2, stall_s: 4.0, ..ChaosSpec::default() };
    r.site_chaos = chaos;
    r.straggler_deadline_ms = 1_000;
    r.expect = Expectation::Degrade(2);
    recipes.push(r);

    let mut r = Recipe::base(
        "skew-quantity-dad",
        "geometric quantity skew (ratio 0.5): row-weighted averaging under unequal shards",
        AlgoSpec::Dad,
    );
    r.partition = Partition::QuantitySkew(0.5);
    recipes.push(r);

    let mut r = Recipe::base(
        "drop-uplink-dsgd",
        "a lossy uplink drops a payload frame mid-exchange: clean failure, not a hang",
        AlgoSpec::Dsgd,
    );
    let mut chaos = vec![ChaosSpec::default(); 3];
    // Site 1's third frame (after the step-meta ship and step-sync recv)
    // is the first step's gradient uplink: the aggregator times out inside
    // the exchange, where degradation is not sound.
    chaos[1] = ChaosSpec { seed: 77, drop_every: 3, ..ChaosSpec::default() };
    r.site_chaos = chaos;
    r.straggler_deadline_ms = 1_500;
    r.expect = Expectation::Fail("mid-exchange".into());
    recipes.push(r);

    let mut r = Recipe::base(
        "mid-drop-dad-p2p",
        "dad-p2p cannot shrink its mesh: a lost site must fail cleanly, naming it",
        AlgoSpec::DadP2p,
    );
    let mut chaos = vec![ChaosSpec::default(); 3];
    chaos[2] = ChaosSpec { seed: 23, disconnect_at_step: 2, ..ChaosSpec::default() };
    r.site_chaos = chaos;
    r.straggler_deadline_ms = 5_000;
    r.expect = Expectation::Fail("cannot continue with survivors".into());
    recipes.push(r);

    let mut r = Recipe::base(
        "edad-periodic-reject",
        "the documented edAD desync: periodic schedules are rejected up front",
        AlgoSpec::Edad,
    );
    r.spec.schedule = Schedule::from_sync_every(3);
    r.expect = Expectation::Fail("edad over the wire requires --sync-every 1".into());
    recipes.push(r);

    let mut r = Recipe::base(
        "edad-lm-reject",
        "edAD has no delta recomputation for attention: the LM pairing is rejected up front",
        AlgoSpec::Edad,
    );
    r.dataset = "lm".into();
    r.expect = Expectation::Fail("edad".into());
    recipes.push(r);

    recipes
}

/// Look up a named recipe.
pub fn find_recipe(name: &str) -> Option<Recipe> {
    named_recipes().into_iter().find(|r| r.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_findable() {
        let recipes = named_recipes();
        assert!(recipes.len() >= 10, "registry shrank to {}", recipes.len());
        let mut names: Vec<&str> = recipes.iter().map(|r| r.name.as_str()).collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate recipe names");
        for r in &recipes {
            assert!(find_recipe(&r.name).is_some(), "{} not findable", r.name);
            assert!(!r.summary.is_empty());
            // Per-site chaos never indexes out of range.
            assert!(r.site_chaos.len() <= r.spec.n_sites, "{}", r.name);
        }
        assert!(find_recipe("no-such-recipe").is_none());
    }

    #[test]
    fn expectation_spellings_roundtrip() {
        for s in ["converge", "degrade:2", "fail:boom"] {
            assert_eq!(Expectation::parse(s).unwrap().name(), s);
        }
        assert!(Expectation::parse("degrade:x").is_err());
        assert!(Expectation::parse("explode").is_err());
    }

    #[test]
    fn recipe_parses_from_toml_with_site_chaos() {
        let text = r#"
name = "custom-drop"
summary = "one flaky site"
expect = "degrade:1"
strict = false
straggler_deadline_ms = 750

[train]
algo = "dsgd"
dataset = "mnist"
sites = 2
batch = 8
epochs = 1
sync_every = 1
partition = "skew:0.5"

[chaos.site.1]
seed = 5
link = "wan"
jitter_ms = 2
disconnect_at_step = 4
"#;
        let r = Recipe::from_toml(text).unwrap();
        assert_eq!(r.name, "custom-drop");
        assert_eq!(r.spec.n_sites, 2);
        assert!(matches!(r.spec.algo, AlgoSpec::Dsgd));
        assert_eq!(r.partition, Partition::QuantitySkew(0.5));
        assert_eq!(r.straggler_deadline_ms, 750);
        assert_eq!(r.expect, Expectation::Degrade(1));
        assert!(r.chaos_for(0).is_quiet());
        let c1 = r.chaos_for(1);
        assert_eq!(c1.seed, 5);
        assert_eq!(c1.disconnect_at_step, 4);
        assert!(c1.link_cost.is_some());
        // Unknown fields fail loudly, not silently.
        assert!(Recipe::from_toml("name = \"x\"\nexpect = \"explode\"").is_err());
        assert!(Recipe::from_toml("summary = \"missing name\"").is_err());
    }
}

//! Execute a [`Recipe`](super::Recipe) end-to-end over real TCP sockets:
//! one aggregator plus `n_sites` in-process site threads, each wrapped in
//! its own [`ChaosTransport`] — the same topology `dad serve` / `dad join`
//! run as separate OS processes, compressed into one process so recipes
//! are runnable from `dad chaos` and from `cargo test` without launcher
//! scripts. (The CI recipe matrix additionally re-runs recipes through the
//! real multi-process path via `.github/scripts/remote_smoke.sh`.)
//!
//! The runner never hangs: the handshake, every aggregator read and every
//! site read are bounded by the recipe's deadlines, and when the serve
//! side finishes (cleanly or not) its sockets close, which unblocks any
//! surviving site thread with a clean link error.

use std::io;
use std::thread;
use std::time::Duration;

use super::{Expectation, Recipe};
use crate::coordinator::{
    build_task, join_training, serve_training, validate_dataset_algo, validate_remote,
    FaultPolicy, RemoteConfig, Scale, TrainLog, TrainTask,
};
use crate::dist::{ChaosTransport, Ledger, TcpAgg, TcpAggListener, TcpSite, Transport};

/// What one recipe run produced: at most one of `log` / `error`, plus the
/// per-site outcomes (informational — a degraded run *expects* the retired
/// sites to report link errors).
#[derive(Debug)]
pub struct RecipeReport {
    /// The aggregator's per-epoch metrics when the run completed.
    pub log: Option<TrainLog>,
    /// The aggregator's clean failure when it did not.
    pub error: Option<io::Error>,
    /// `(site id, error)` for every site thread that ended with an error;
    /// `usize::MAX` marks a site that failed before the handshake assigned
    /// it an id.
    pub site_errors: Vec<(usize, String)>,
}

impl RecipeReport {
    /// Assert the run matched `recipe.expect`; `Err` carries a diagnostic
    /// naming what diverged. This is the single assertion the CLI
    /// (`dad chaos`) and `tests/chaos_recipes.rs` both apply.
    pub fn check(&self, recipe: &Recipe) -> Result<(), String> {
        match &recipe.expect {
            Expectation::Fail(text) => match &self.error {
                None => Err(format!(
                    "{}: expected a clean failure containing {text:?}, but the run completed",
                    recipe.name
                )),
                Some(e) if !e.to_string().contains(text.as_str()) => Err(format!(
                    "{}: error does not mention {text:?}: {e}",
                    recipe.name
                )),
                Some(_) => Ok(()),
            },
            expect => {
                if let Some(e) = &self.error {
                    return Err(format!("{}: expected completion, got: {e}", recipe.name));
                }
                let log = self
                    .log
                    .as_ref()
                    .ok_or_else(|| format!("{}: run produced no log", recipe.name))?;
                let last = log
                    .epochs
                    .last()
                    .ok_or_else(|| format!("{}: log has no epochs", recipe.name))?;
                if !last.train_loss.is_finite() {
                    return Err(format!(
                        "{}: final loss is not finite ({})",
                        recipe.name, last.train_loss
                    ));
                }
                let want = match expect {
                    Expectation::Converge => recipe.spec.n_sites,
                    Expectation::Degrade(k) => *k,
                    Expectation::Fail(_) => unreachable!(),
                };
                if last.sites_live != want {
                    return Err(format!(
                        "{}: expected {want} surviving site(s), final epoch reports {}",
                        recipe.name, last.sites_live
                    ));
                }
                Ok(())
            }
        }
    }
}

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidInput, msg)
}

fn millis(ms: u64) -> Option<Duration> {
    (ms > 0).then(|| Duration::from_millis(ms))
}

/// One site process, compressed into a thread: dial, learn the handshake
/// id, arm the read deadline, receive the config, wrap the socket in this
/// site's chaos schedule, and train.
fn site_main(addr: String, recipe: Recipe) -> (usize, io::Result<TrainLog>) {
    let site = match TcpSite::connect_retry(&addr, Duration::from_secs(10)) {
        Ok(s) => s,
        Err(e) => return (usize::MAX, Err(e)),
    };
    // The handshake assigns ids in accept order, so which *thread* this is
    // says nothing about which *site* it is — the chaos spec must be
    // selected by the wire-assigned id or the schedule would be
    // nondeterministic across runs.
    let site_id = site.site_id();
    (site_id, site_run(site, site_id, &recipe))
}

fn site_run(site: TcpSite, site_id: usize, recipe: &Recipe) -> io::Result<TrainLog> {
    if let Some(t) = millis(u64::from(recipe.recv_timeout_ms)) {
        site.set_recv_timeout(Some(t))?;
    }
    let mut t: Box<dyn Transport> = Box::new(site);
    let cfg = RemoteConfig::recv(t.as_mut())?;
    let chaos = recipe.chaos_for(site_id);
    if !chaos.is_quiet() {
        // `paced`: over real sockets the injected delay must be wall-clock
        // visible or the aggregator's straggler deadline could never fire.
        t = Box::new(ChaosTransport::paced(t, chaos, site_id as u64));
    }
    let scale = Scale::parse(&cfg.scale).unwrap_or(Scale::Quick);
    let task = build_task(&cfg.dataset, scale, cfg.spec.n_sites, cfg.spec.seed)
        .map_err(invalid)?
        .repartition(cfg.partition, cfg.spec.seed);
    let mut ledger = Ledger::new();
    match task {
        TrainTask::Dense { train_ds, shards, model, .. } => {
            join_training(t.as_mut(), &mut ledger, &cfg.spec, model, &train_ds, &shards, site_id)
        }
        TrainTask::Seq { train_ds, shards, model, .. } => {
            join_training(t.as_mut(), &mut ledger, &cfg.spec, model, &train_ds, &shards, site_id)
        }
        TrainTask::Tokens { train_ds, shards, model, .. } => {
            join_training(t.as_mut(), &mut ledger, &cfg.spec, model, &train_ds, &shards, site_id)
        }
    }
}

/// The aggregator half: bounded handshake, straggler deadline, config
/// broadcast, then the standard serve loop under the recipe's fault
/// policy. Owns `agg`, so returning (cleanly or not) closes every site
/// socket and unblocks the site threads.
fn serve_main(listener: TcpAggListener, recipe: &Recipe, strict: bool) -> io::Result<TrainLog> {
    let mut agg: TcpAgg = listener.accept_sites_deadline(millis(recipe.handshake_timeout_ms))?;
    agg.set_recv_timeout(millis(recipe.straggler_deadline_ms))?;
    RemoteConfig {
        spec: recipe.spec.clone(),
        dataset: recipe.dataset.clone(),
        scale: recipe.scale.clone(),
        recv_timeout_ms: recipe.recv_timeout_ms,
        partition: recipe.partition,
        resume: false,
    }
    .send(&mut agg)?;
    let scale = Scale::parse(&recipe.scale).unwrap_or(Scale::Quick);
    let task = build_task(&recipe.dataset, scale, recipe.spec.n_sites, recipe.spec.seed)
        .map_err(invalid)?
        .repartition(recipe.partition, recipe.spec.seed);
    let policy = if strict { FaultPolicy::strict() } else { FaultPolicy::degrade() };
    let spec = &recipe.spec;
    let mut ledger = Ledger::new();
    match task {
        TrainTask::Dense { train_ds, test_ds, shards, model } => {
            serve_training(&mut agg, &mut ledger, spec, model, &train_ds, &shards, &test_ds, policy)
        }
        TrainTask::Seq { train_ds, test_ds, shards, model } => {
            serve_training(&mut agg, &mut ledger, spec, model, &train_ds, &shards, &test_ds, policy)
        }
        TrainTask::Tokens { train_ds, test_ds, shards, model } => {
            serve_training(&mut agg, &mut ledger, spec, model, &train_ds, &shards, &test_ds, policy)
        }
    }
}

/// Run `recipe` start to finish and report what happened — completion
/// with metrics, or a clean error; never a hang or a panic. `strict`
/// overrides the recipe's own fault policy (the CLI's `--strict`).
///
/// The edAD rejection recipes return their clean error here, *before* any
/// socket is opened — mirroring `dad serve`'s fail-on-the-operator's-
/// terminal contract.
pub fn run_recipe(recipe: &Recipe, strict: bool) -> RecipeReport {
    let fail = |error: io::Error| RecipeReport {
        log: None,
        error: Some(error),
        site_errors: vec![],
    };
    if let Err(e) = validate_dataset_algo(&recipe.dataset, &recipe.spec.algo) {
        return fail(io::Error::new(io::ErrorKind::Unsupported, e));
    }
    if let Err(e) = validate_remote(&recipe.spec) {
        return fail(e);
    }
    let listener = match TcpAgg::bind("127.0.0.1:0", recipe.spec.n_sites) {
        Ok(l) => l,
        Err(e) => return fail(e),
    };
    let addr = match listener.local_addr() {
        Ok(a) => a.to_string(),
        Err(e) => return fail(e),
    };
    let handles: Vec<_> = (0..recipe.spec.n_sites)
        .map(|_| {
            let addr = addr.clone();
            let r = recipe.clone();
            thread::spawn(move || site_main(addr, r))
        })
        .collect();
    let served = serve_main(listener, recipe, strict || recipe.strict);
    // serve_main dropped the aggregator: surviving site threads now see
    // closed sockets (or their own recv deadline) and terminate promptly.
    let mut site_errors = Vec::new();
    for h in handles {
        match h.join() {
            Ok((_, Ok(_))) => {}
            Ok((site, Err(e))) => site_errors.push((site, e.to_string())),
            Err(_) => site_errors.push((usize::MAX, "site thread panicked".to_string())),
        }
    }
    match served {
        Ok(log) => RecipeReport { log: Some(log), error: None, site_errors },
        Err(e) => RecipeReport { log: None, error: Some(e), site_errors },
    }
}

//! Execute a [`Recipe`](super::Recipe) end-to-end over real TCP sockets:
//! one aggregator plus `n_sites` in-process site threads, each wrapped in
//! its own [`ChaosTransport`] — the same topology `dad serve` / `dad join`
//! run as separate OS processes, compressed into one process so recipes
//! are runnable from `dad chaos` and from `cargo test` without launcher
//! scripts. (The CI recipe matrix additionally re-runs recipes through the
//! real multi-process path via `.github/scripts/remote_smoke.sh`.)
//!
//! The runner never hangs: the handshake, every aggregator read and every
//! site read are bounded by the recipe's deadlines, and when the serve
//! side finishes (cleanly or not) its sockets close, which unblocks any
//! surviving site thread with a clean link error.

use std::io;
use std::thread;
use std::time::Duration;

use super::{Expectation, Recipe};
use crate::coordinator::{
    build_task, join_training, relay_training, serve_training, validate_dataset_algo,
    validate_remote, validate_remote_topology, FaultPolicy, RemoteConfig, ResumeMode, Scale,
    Topology, TrainLog, TrainTask,
};
use crate::dist::{ChaosTransport, Ledger, TcpAgg, TcpAggListener, TcpSite, Transport};

/// What one recipe run produced: at most one of `log` / `error`, plus the
/// per-site outcomes (informational — a degraded run *expects* the retired
/// sites to report link errors).
#[derive(Debug)]
pub struct RecipeReport {
    /// The aggregator's per-epoch metrics when the run completed.
    pub log: Option<TrainLog>,
    /// The aggregator's clean failure when it did not.
    pub error: Option<io::Error>,
    /// `(site id, error)` for every site thread that ended with an error;
    /// `usize::MAX` marks a site that failed before the handshake assigned
    /// it an id.
    pub site_errors: Vec<(usize, String)>,
}

impl RecipeReport {
    /// Assert the run matched `recipe.expect`; `Err` carries a diagnostic
    /// naming what diverged. This is the single assertion the CLI
    /// (`dad chaos`) and `tests/chaos_recipes.rs` both apply.
    pub fn check(&self, recipe: &Recipe) -> Result<(), String> {
        match &recipe.expect {
            Expectation::Fail(text) => match &self.error {
                None => Err(format!(
                    "{}: expected a clean failure containing {text:?}, but the run completed",
                    recipe.name
                )),
                Some(e) if !e.to_string().contains(text.as_str()) => Err(format!(
                    "{}: error does not mention {text:?}: {e}",
                    recipe.name
                )),
                Some(_) => Ok(()),
            },
            expect => {
                if let Some(e) = &self.error {
                    return Err(format!("{}: expected completion, got: {e}", recipe.name));
                }
                let log = self
                    .log
                    .as_ref()
                    .ok_or_else(|| format!("{}: run produced no log", recipe.name))?;
                let last = log
                    .epochs
                    .last()
                    .ok_or_else(|| format!("{}: log has no epochs", recipe.name))?;
                if !last.train_loss.is_finite() {
                    return Err(format!(
                        "{}: final loss is not finite ({})",
                        recipe.name, last.train_loss
                    ));
                }
                let want = match expect {
                    Expectation::Converge => recipe.spec.n_sites,
                    Expectation::Degrade(k) => *k,
                    Expectation::Fail(_) => unreachable!(),
                };
                if last.sites_live != want {
                    return Err(format!(
                        "{}: expected {want} surviving site(s), final epoch reports {}",
                        recipe.name, last.sites_live
                    ));
                }
                Ok(())
            }
        }
    }
}

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidInput, msg)
}

fn millis(ms: u64) -> Option<Duration> {
    (ms > 0).then(|| Duration::from_millis(ms))
}

/// One site process, compressed into a thread: dial, learn the handshake
/// id, arm the read deadline, receive the config, wrap the socket in this
/// site's chaos schedule, and train.
fn site_main(addr: String, recipe: Recipe) -> (usize, io::Result<TrainLog>) {
    let site = match TcpSite::connect_retry(&addr, Duration::from_secs(10)) {
        Ok(s) => s,
        Err(e) => return (usize::MAX, Err(e)),
    };
    // The handshake assigns ids in accept order, so which *thread* this is
    // says nothing about which *site* it is — the chaos spec must be
    // selected by the wire-assigned id or the schedule would be
    // nondeterministic across runs.
    let site_id = site.site_id();
    (site_id, site_run(site, site_id, &recipe))
}

fn site_run(site: TcpSite, site_id: usize, recipe: &Recipe) -> io::Result<TrainLog> {
    if let Some(t) = millis(u64::from(recipe.recv_timeout_ms)) {
        site.set_recv_timeout(Some(t))?;
    }
    let mut t: Box<dyn Transport> = Box::new(site);
    let cfg = RemoteConfig::recv(t.as_mut())?;
    let chaos = recipe.chaos_for(site_id);
    if !chaos.is_quiet() {
        // `paced`: over real sockets the injected delay must be wall-clock
        // visible or the aggregator's straggler deadline could never fire.
        t = Box::new(ChaosTransport::paced(t, chaos, site_id as u64));
    }
    let scale = Scale::parse(&cfg.scale).unwrap_or(Scale::Quick);
    let task = build_task(&cfg.dataset, scale, cfg.spec.n_sites, cfg.spec.seed)
        .map_err(invalid)?
        .repartition(cfg.partition, cfg.spec.seed);
    let mut ledger = Ledger::new();
    match task {
        TrainTask::Dense { train_ds, shards, model, .. } => {
            join_training(t.as_mut(), &mut ledger, &cfg.spec, model, &train_ds, &shards, site_id)
        }
        TrainTask::Seq { train_ds, shards, model, .. } => {
            join_training(t.as_mut(), &mut ledger, &cfg.spec, model, &train_ds, &shards, site_id)
        }
        TrainTask::Tokens { train_ds, shards, model, .. } => {
            join_training(t.as_mut(), &mut ledger, &cfg.spec, model, &train_ds, &shards, site_id)
        }
    }
}

/// The aggregator half: bounded handshake, straggler deadline, config
/// broadcast, then the standard serve loop under the recipe's fault
/// policy. Owns `agg`, so returning (cleanly or not) closes every site
/// socket and unblocks the site threads.
fn serve_main(listener: TcpAggListener, recipe: &Recipe, strict: bool) -> io::Result<TrainLog> {
    let links = recipe.tree_links.min(recipe.spec.n_sites);
    let mut agg: TcpAgg = if links == 0 {
        listener.accept_sites_deadline(millis(recipe.handshake_timeout_ms))?
    } else {
        let pending = listener.accept_hellos_deadline(millis(recipe.handshake_timeout_ms))?;
        if pending.n_links() != links {
            return Err(invalid(format!(
                "tree recipe expected {links} root links, got {}",
                pending.n_links()
            )));
        }
        pending.welcome_all(0, recipe.spec.n_sites as u32)?
    };
    agg.set_recv_timeout(millis(recipe.straggler_deadline_ms))?;
    RemoteConfig {
        spec: recipe.spec.clone(),
        dataset: recipe.dataset.clone(),
        scale: recipe.scale.clone(),
        recv_timeout_ms: recipe.recv_timeout_ms,
        partition: recipe.partition,
        resume: ResumeMode::Fresh,
    }
    .send(&mut agg)?;
    let scale = Scale::parse(&recipe.scale).unwrap_or(Scale::Quick);
    let task = build_task(&recipe.dataset, scale, recipe.spec.n_sites, recipe.spec.seed)
        .map_err(invalid)?
        .repartition(recipe.partition, recipe.spec.seed);
    let policy = if strict { FaultPolicy::strict() } else { FaultPolicy::degrade() };
    let spec = &recipe.spec;
    let mut ledger = Ledger::new();
    match task {
        TrainTask::Dense { train_ds, test_ds, shards, model } => {
            serve_training(&mut agg, &mut ledger, spec, model, &train_ds, &shards, &test_ds, policy)
        }
        TrainTask::Seq { train_ds, test_ds, shards, model } => {
            serve_training(&mut agg, &mut ledger, spec, model, &train_ds, &shards, &test_ds, policy)
        }
        TrainTask::Tokens { train_ds, test_ds, shards, model } => {
            serve_training(&mut agg, &mut ledger, spec, model, &train_ds, &shards, &test_ds, policy)
        }
    }
}

/// One relay process compressed into a thread (the `dad relay` role):
/// accept this subtree's leaves, dial the aggregator declaring all of
/// them, assign their global leaf ids from the parent's welcome, forward
/// the config verbatim, and run the reduce-and-forward loop until the
/// run ends.
fn relay_main(parent_addr: String, listener: TcpAggListener, recipe: Recipe) -> io::Result<()> {
    let pending = listener.accept_hellos_deadline(millis(recipe.handshake_timeout_ms))?;
    let total = pending.total_leaves();
    let mut parent =
        TcpSite::connect_retry_with_leaves(&parent_addr, total, Duration::from_secs(10))?;
    let leaf_start = parent.site_id() as u32;
    let global_total = parent.n_sites() as u32;
    let mut children = pending.welcome_all(leaf_start, global_total)?;
    children.set_recv_timeout(millis(recipe.straggler_deadline_ms))?;
    let cfg = RemoteConfig::recv_forward(&mut parent, &mut children)?;
    if let Some(t) = millis(u64::from(cfg.recv_timeout_ms)) {
        parent.set_recv_timeout(Some(t))?;
    }
    let scale = Scale::parse(&cfg.scale).unwrap_or(Scale::Quick);
    let task = build_task(&cfg.dataset, scale, cfg.spec.n_sites, cfg.spec.seed)
        .map_err(invalid)?
        .repartition(cfg.partition, cfg.spec.seed);
    let policy = if recipe.strict { FaultPolicy::strict() } else { FaultPolicy::degrade() };
    let mut parent_ledger = Ledger::new();
    let mut child_ledger = Ledger::new();
    match task {
        TrainTask::Dense { shards, model, .. } => relay_training(
            &mut parent,
            &mut children,
            &mut parent_ledger,
            &mut child_ledger,
            &cfg,
            &shards,
            policy,
            model,
        ),
        TrainTask::Seq { shards, model, .. } => relay_training(
            &mut parent,
            &mut children,
            &mut parent_ledger,
            &mut child_ledger,
            &cfg,
            &shards,
            policy,
            model,
        ),
        TrainTask::Tokens { shards, model, .. } => relay_training(
            &mut parent,
            &mut children,
            &mut parent_ledger,
            &mut child_ledger,
            &cfg,
            &shards,
            policy,
            model,
        ),
    }
}

/// Run `recipe` start to finish and report what happened — completion
/// with metrics, or a clean error; never a hang or a panic. `strict`
/// overrides the recipe's own fault policy (the CLI's `--strict`).
///
/// The edAD rejection recipes return their clean error here, *before* any
/// socket is opened — mirroring `dad serve`'s fail-on-the-operator's-
/// terminal contract.
pub fn run_recipe(recipe: &Recipe, strict: bool) -> RecipeReport {
    let fail = |error: io::Error| RecipeReport {
        log: None,
        error: Some(error),
        site_errors: vec![],
    };
    if let Err(e) = validate_dataset_algo(&recipe.dataset, &recipe.spec.algo) {
        return fail(io::Error::new(io::ErrorKind::Unsupported, e));
    }
    if let Err(e) = validate_remote(&recipe.spec) {
        return fail(e);
    }
    let links = recipe.tree_links.min(recipe.spec.n_sites);
    if links > 0 {
        let topo = Topology::Tree { root_links: links };
        if let Err(e) = validate_remote_topology(&recipe.spec, &topo) {
            return fail(e);
        }
    }
    let listener = match TcpAgg::bind("127.0.0.1:0", recipe.spec.n_sites) {
        Ok(l) => l,
        Err(e) => return fail(e),
    };
    let addr = match listener.local_addr() {
        Ok(a) => a.to_string(),
        Err(e) => return fail(e),
    };
    let mut handles = Vec::new();
    let mut relay_handles = Vec::new();
    if links == 0 {
        for _ in 0..recipe.spec.n_sites {
            let addr = addr.clone();
            let r = recipe.clone();
            handles.push(thread::spawn(move || site_main(addr, r)));
        }
    } else {
        // Bind every relay listener before spawning anything, so a bind
        // failure is a clean early return rather than a handshake timeout.
        let n = recipe.spec.n_sites;
        let mut groups = Vec::with_capacity(links);
        for g in 0..links {
            let size = n / links + usize::from(g < n % links);
            match TcpAgg::bind("127.0.0.1:0", size) {
                Ok(l) => groups.push((l, size)),
                Err(e) => return fail(e),
            }
        }
        for (relay_listener, size) in groups {
            let relay_addr = match relay_listener.local_addr() {
                Ok(a) => a.to_string(),
                Err(e) => return fail(e),
            };
            for _ in 0..size {
                let a = relay_addr.clone();
                let r = recipe.clone();
                handles.push(thread::spawn(move || site_main(a, r)));
            }
            let parent = addr.clone();
            let mut r = recipe.clone();
            // The CLI's --strict must reach the relay's fault policy too.
            r.strict = strict || recipe.strict;
            relay_handles.push(thread::spawn(move || relay_main(parent, relay_listener, r)));
        }
    }
    let served = serve_main(listener, recipe, strict || recipe.strict);
    // serve_main dropped the aggregator: surviving relay and site threads
    // now see closed sockets (or their own recv deadline) and terminate
    // promptly.
    let mut site_errors = Vec::new();
    for h in handles {
        match h.join() {
            Ok((_, Ok(_))) => {}
            Ok((site, Err(e))) => site_errors.push((site, e.to_string())),
            Err(_) => site_errors.push((usize::MAX, "site thread panicked".to_string())),
        }
    }
    for h in relay_handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => site_errors.push((usize::MAX, format!("relay: {e}"))),
            Err(_) => site_errors.push((usize::MAX, "relay thread panicked".to_string())),
        }
    }
    match served {
        Ok(log) => RecipeReport { log: Some(log), error: None, site_errors },
        Err(e) => RecipeReport { log: None, error: Some(e), site_errors },
    }
}

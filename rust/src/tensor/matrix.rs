//! Dense row-major f32 matrix — the storage type for every statistic the
//! paper ships: activations A (N x h), deltas Δ (N x h'), weights W (h x h'),
//! and low-rank factors Q/G (r x h).

use super::rng::Rng;

/// Dense row-major f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows * self.cols <= 16 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Constant-filled matrix.
    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    /// Wrap a row-major value vector (length must equal rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Build elementwise from `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Standard-normal entries scaled by `std`.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal() * std);
        }
        Matrix { rows, cols, data }
    }

    /// Uniform entries in [lo, hi).
    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.uniform_in(lo, hi));
        }
        Matrix { rows, cols, data }
    }

    /// The n x n identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Total element count.
    #[inline]
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Bytes on the wire (f32): the unit of the paper's bandwidth accounting.
    #[inline]
    pub fn wire_bytes(&self) -> u64 {
        (self.numel() * 4) as u64
    }

    /// Row-major value slice.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major value slice.
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the row-major value vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Cache-blocked out-of-place transpose.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on the big stat matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Vertical concatenation — the aggregator's `vertcat` in Algorithms 1-2.
    pub fn vertcat(parts: &[&Matrix]) -> Matrix {
        assert!(!parts.is_empty());
        let cols = parts[0].cols;
        let rows: usize = parts.iter().map(|m| m.rows).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for m in parts {
            assert_eq!(m.cols, cols, "vertcat column mismatch");
            data.extend_from_slice(&m.data);
        }
        Matrix { rows, cols, data }
    }

    /// Rows [lo, hi) as a new matrix (a site's shard of a broadcast stat).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.rows);
        Matrix {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }

    /// Gather the given rows into a new matrix (mini-batch assembly).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Matrix { rows: idx.len(), cols: self.cols, data }
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    /// Elementwise combine with `other` (shapes must match).
    pub fn zip_map(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise sum.
    pub fn add(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a + b)
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a - b)
    }

    /// Elementwise (Hadamard) product — the ⊙ of paper eq. (2)/(3)/(5).
    pub fn hadamard(&self, other: &Matrix) -> Matrix {
        self.zip_map(other, |a, b| a * b)
    }

    /// Scalar multiply into a new matrix.
    pub fn scale(&self, alpha: f32) -> Matrix {
        self.map(|x| alpha * x)
    }

    /// Scalar multiply in place.
    pub fn scale_inplace(&mut self, alpha: f32) {
        self.map_inplace(|x| alpha * x);
    }

    /// Column sums (bias gradients: scale * 1ᵀ Δ).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt() as f32
    }

    /// max_ij |a_ij - b_ij| — the metric of the paper's Table 2.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Largest absolute entry (0 for empty matrices).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).fold(0.0f32, f32::max)
    }

    /// True iff all entries are finite (NaN/Inf guard used in tests and the
    /// coordinator's failure-injection checks).
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        let _ = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(37, 53, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        let t = m.transpose();
        assert_eq!(t[(5, 7)], m[(7, 5)]);
    }

    #[test]
    fn vertcat_slice_roundtrip() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(4, 6, 1.0, &mut rng);
        let b = Matrix::randn(3, 6, 1.0, &mut rng);
        let cat = Matrix::vertcat(&[&a, &b]);
        assert_eq!(cat.shape(), (7, 6));
        assert_eq!(cat.slice_rows(0, 4), a);
        assert_eq!(cat.slice_rows(4, 7), b);
    }

    #[test]
    fn col_sums() {
        let m = Matrix::from_fn(3, 2, |i, j| (i + j) as f32);
        assert_eq!(m.col_sums(), vec![3.0, 6.0]);
    }

    #[test]
    fn hadamard_and_axpy() {
        let a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![4.0, 5.0, 6.0]);
        assert_eq!(a.hadamard(&b).data(), &[4.0, 10.0, 18.0]);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        assert_eq!(c.data(), &[9.0, 12.0, 15.0]);
    }

    #[test]
    fn max_abs_diff() {
        let a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![1.0, 2.5, 2.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }

    #[test]
    fn gather_rows() {
        let m = Matrix::from_fn(5, 2, |i, _| i as f32);
        let g = m.gather_rows(&[4, 0, 2]);
        assert_eq!(g.data(), &[4.0, 4.0, 0.0, 0.0, 2.0, 2.0]);
    }

    #[test]
    fn wire_bytes() {
        assert_eq!(Matrix::zeros(32, 1024).wire_bytes(), 32 * 1024 * 4);
    }
}

//! From-scratch f32 tensor substrate: dense matrices, blocked/packed matmul
//! kernels over a persistent worker pool, a deterministic PRNG, and a
//! reusable step-workspace arena.
//!
//! Everything the coordinator computes natively (forward passes, the backward
//! delta recurrence, gradient outer products, structured power iterations)
//! runs on these kernels; the PJRT runtime provides an alternative backend
//! executing the AOT-compiled JAX/Pallas artifacts for the same math.

pub mod matrix;
pub mod ops;
pub mod parallel;
pub mod pool;
pub mod rng;
pub mod workspace;

pub use matrix::Matrix;
pub use ops::{
    dot, matmul, matmul_into, matmul_nt, matmul_nt_into, matmul_tn, matmul_tn_into, matvec,
    matvec_into, matvec_t, matvec_t_into,
};
pub use rng::Rng;
pub use workspace::Workspace;

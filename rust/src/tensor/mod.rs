//! From-scratch f32 tensor substrate: dense matrices, matmul kernels, a
//! deterministic PRNG, and a minimal thread-parallel helper.
//!
//! Everything the coordinator computes natively (forward passes, the backward
//! delta recurrence, gradient outer products, structured power iterations)
//! runs on these kernels; the PJRT runtime provides an alternative backend
//! executing the AOT-compiled JAX/Pallas artifacts for the same math.

pub mod matrix;
pub mod ops;
pub mod parallel;
pub mod rng;

pub use matrix::Matrix;
pub use ops::{dot, matmul, matmul_nt, matmul_tn, matvec, matvec_t};
pub use rng::Rng;

//! Matrix-multiply kernels — the compute hot path of the native engine.
//!
//! Three variants cover everything the paper's math needs without ever
//! materializing a transpose:
//!   matmul     C = A B        forward passes, Δ_{i+1} Wᵀ is matmul_nt
//!   matmul_tn  C = Aᵀ B       gradient outer products  AᵀΔ   (eq. 4)
//!   matmul_nt  C = A Bᵀ       backward delta step      ΔWᵀ   (eq. 3/5)
//!
//! Layout: ikj loops with row-panel accumulation (unit-stride inner loops
//! that LLVM auto-vectorizes), parallelized over output rows via scoped
//! threads. See EXPERIMENTS.md §Perf for the measured roofline.

use super::matrix::Matrix;
use super::parallel::parallel_rows_mut;

/// Minimum FLOPs before a matmul is worth threading (tuned in §Perf).
const PAR_FLOP_THRESHOLD: usize = 1 << 20;

#[inline]
fn min_rows_for(total_rows: usize, flops: usize) -> usize {
    if flops < PAR_FLOP_THRESHOLD {
        total_rows // single chunk => serial
    } else {
        1
    }
}

/// C = A B.  A: (m,k), B: (k,n) -> (m,n).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul inner dim: {:?} x {:?}", a.shape(), b.shape());
    let mut out = Matrix::zeros(m, n);
    let flops = 2 * m * k * n;
    let bd = b.data();
    let ad = a.data();
    parallel_rows_mut(out.data_mut(), n, min_rows_for(m, flops), |start, chunk| {
        for (r, crow) in chunk.chunks_mut(n).enumerate() {
            let i = start + r;
            let arow = &ad[i * k..(i + 1) * k];
            for (kk, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue; // ReLU activations are ~50% zeros
                }
                let brow = &bd[kk * n..(kk + 1) * n];
                for (c, &bv) in crow.iter_mut().zip(brow) {
                    *c += aik * bv;
                }
            }
        }
    });
    out
}

/// C = Aᵀ B.  A: (k,m), B: (k,n) -> (m,n).  The gradient outer product:
/// k is the (small) batch dimension, m/n are layer widths.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul_tn inner dim: {:?} x {:?}", a.shape(), b.shape());
    let mut out = Matrix::zeros(m, n);
    let flops = 2 * m * k * n;
    let ad = a.data();
    let bd = b.data();
    parallel_rows_mut(out.data_mut(), n, min_rows_for(m, flops), |start, chunk| {
        let rows = chunk.len() / n;
        for kk in 0..k {
            let brow = &bd[kk * n..(kk + 1) * n];
            let acol = &ad[kk * m..(kk + 1) * m];
            for r in 0..rows {
                let aik = acol[start + r];
                if aik == 0.0 {
                    continue;
                }
                let crow = &mut chunk[r * n..(r + 1) * n];
                for (c, &bv) in crow.iter_mut().zip(brow) {
                    *c += aik * bv;
                }
            }
        }
    });
    out
}

/// C = A Bᵀ.  A: (m,k), B: (n,k) -> (m,n).  The backward delta contraction.
///
/// Two regimes (§Perf iteration 2): for large problems, transposing B once
/// (O(nk), cache-blocked) and running the ikj kernel beats the dot-product
/// kernel ~1.8x — the ikj inner loop streams with independent FMA chains,
/// while back-to-back dots stall on the horizontal-add dependency.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (n, k2) = b.shape();
    assert_eq!(k, k2, "matmul_nt inner dim: {:?} x {:?}", a.shape(), b.shape());
    let flops = 2 * m * k * n;
    if flops >= 1 << 22 {
        return matmul(a, &b.transpose());
    }
    let mut out = Matrix::zeros(m, n);
    let ad = a.data();
    let bd = b.data();
    parallel_rows_mut(out.data_mut(), n, min_rows_for(m, flops), |start, chunk| {
        for (r, crow) in chunk.chunks_mut(n).enumerate() {
            let i = start + r;
            let arow = &ad[i * k..(i + 1) * k];
            for (j, c) in crow.iter_mut().enumerate() {
                let brow = &bd[j * k..(j + 1) * k];
                *c = dot(arow, brow);
            }
        }
    });
    out
}

/// Unit-stride dot product with 8-lane unrolled accumulators.
///
/// chunks_exact + zip lets LLVM elide every bounds check and vectorize;
/// the indexed version of this loop ran at ~2.5 GFLOP/s inside matmul_nt,
/// this one at ~9 GFLOP/s (EXPERIMENTS.md §Perf, L3 iteration 1).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let rx = xc.remainder();
    let ry = yc.remainder();
    for (xs, ys) in xc.zip(yc) {
        for l in 0..8 {
            acc[l] += xs[l] * ys[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (a, b) in rx.iter().zip(ry) {
        s += a * b;
    }
    s
}

/// y = A x.  A: (m,n), x: n -> m.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let (m, n) = a.shape();
    assert_eq!(x.len(), n);
    (0..m).map(|i| dot(a.row(i), x)).collect()
}

/// y = Aᵀ x.  A: (m,n), x: m -> n.
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let (m, n) = a.shape();
    assert_eq!(x.len(), m);
    let mut out = vec![0.0f32; n];
    for i in 0..m {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        for (o, &aij) in out.iter_mut().zip(a.row(i)) {
            *o += xi * aij;
        }
    }
    out
}

/// Naive triple-loop oracle (tests + perf baseline).
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (_, n) = b.shape();
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for kk in 0..k {
                s += a[(i, kk)] * b[(kk, j)];
            }
            out[(i, j)] = s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        let d = a.max_abs_diff(b);
        assert!(d < tol, "max abs diff {d} >= {tol}");
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (32, 784, 64), (17, 13, 29)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-3);
        }
    }

    #[test]
    fn tn_equals_transpose_then_mul() {
        let mut rng = Rng::new(2);
        for &(k, m, n) in &[(8, 33, 21), (32, 128, 64), (1, 5, 5)] {
            let a = Matrix::randn(k, m, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            close(&matmul_tn(&a, &b), &matmul(&a.transpose(), &b), 1e-3);
        }
    }

    #[test]
    fn nt_equals_mul_transpose() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(9, 17, 5), (32, 64, 128)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            close(&matmul_nt(&a, &b), &matmul(&a, &b.transpose()), 1e-3);
        }
    }

    #[test]
    fn big_parallel_path_correct() {
        // Force the threaded path (flops > threshold) and compare to naive.
        let mut rng = Rng::new(4);
        let a = Matrix::randn(256, 300, 1.0, &mut rng);
        let b = Matrix::randn(300, 256, 1.0, &mut rng);
        close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-2);
    }

    #[test]
    fn matvec_consistency() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(20, 30, 1.0, &mut rng);
        let x: Vec<f32> = (0..30).map(|i| (i as f32).sin()).collect();
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(30, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..20 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-4);
        }
        let z = matvec_t(&a, &y);
        let zm = matmul_tn(&a, &ym);
        for j in 0..30 {
            assert!((z[j] - zm[(j, 0)]).abs() < 1e-3);
        }
    }

    #[test]
    fn dot_handles_tails() {
        let x: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..7).map(|i| (i + 1) as f32).collect();
        // 0*1+1*2+2*3+3*4+4*5+5*6+6*7 = 112
        assert_eq!(dot(&x, &y), 112.0);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(12, 12, 1.0, &mut rng);
        close(&matmul(&a, &Matrix::identity(12)), &a, 1e-5);
        close(&matmul(&Matrix::identity(12), &a), &a, 1e-5);
    }
}

//! Matrix-multiply kernels — the compute hot path of the native engine.
//!
//! Three variants cover everything the paper's math needs without ever
//! materializing a transpose:
//!   matmul     C = A B        forward passes
//!   matmul_tn  C = Aᵀ B       gradient outer products  AᵀΔ   (eq. 4)
//!   matmul_nt  C = A Bᵀ       backward delta step      ΔWᵀ   (eq. 3/5)
//!
//! Engine layout (EXPERIMENTS.md §Perf): one shared strip kernel
//! (`gemm_strip`) processes four output rows at a time with a unit-stride
//! fused inner loop that LLVM auto-vectorizes, K-blocked so the streamed B
//! panel stays cache-resident. The transposed operands never materialize a
//! full transpose: `matmul_tn` packs a thin transposed A panel per output
//! strip, and `matmul_nt` packs Bᵀ panels on the fly per column block —
//! both into a reusable per-thread scratch buffer, so steady-state calls
//! allocate nothing. Dispatch runs on the persistent pool (`pool::run`),
//! replacing the seed's per-call scoped-thread spawns.
//!
//! Every variant has a `*_into` twin writing a caller-owned output so the
//! training step can reuse `Workspace` buffers (see `tensor::workspace`).

use super::matrix::Matrix;
use super::parallel::{self, parallel_rows_mut};
use crate::obs::trace::span;
use std::cell::RefCell;

/// Minimum FLOPs before a matmul is worth threading. The pool's wake/park
/// handshake is ~µs — far below the seed's thread-spawn cost — so this sits
/// well under the seed's 2^20 (tuned in §Perf).
const PAR_FLOP_THRESHOLD: usize = 1 << 18;

/// K-blocking depth: a KC x n B-panel (KC x jb for `matmul_nt`) stays in
/// L2 while a strip of C rows accumulates against it.
const KC: usize = 256;

/// Column-block width bounds for `matmul_nt`'s column-parallel split.
/// The lower bound keeps blocks worth waking a lane for; the upper bound
/// caps the per-thread packing scratch at (k + m) * MAX_COLS floats — so
/// the paper shapes (k <= 1024) fit inside the pre-warmed scratch
/// (`prewarm_scratch`) on any pool width, keeping the steady state
/// allocation-free — and gives the chunk counter more blocks than lanes
/// for load balancing.
const MIN_COLS: usize = 16;
const MAX_COLS: usize = 192;

#[inline]
fn min_rows_for(total_rows: usize, flops: usize) -> usize {
    if flops < PAR_FLOP_THRESHOLD {
        total_rows // single chunk => serial
    } else {
        1
    }
}

thread_local! {
    /// Per-thread packing scratch (A/Bᵀ panels, column-block accumulators).
    /// Grows to the high-water mark once, then every call is allocation-free.
    static SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

fn with_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    SCRATCH.with(|s| {
        let mut v = s.borrow_mut();
        if v.len() < len {
            v.resize(len, 0.0);
        }
        f(&mut v[..len])
    })
}

/// Pre-size this thread's packing scratch. Pool workers call this once at
/// spawn so that steady-state kernels stay allocation-free regardless of
/// which chunks the dynamic counter hands to which worker (a cold worker
/// growing its scratch mid-training would otherwise be the one stray
/// allocation). 256K floats covers the paper shapes with slack; larger
/// problems grow once and keep the high-water mark.
pub(crate) fn prewarm_scratch() {
    with_scratch(1 << 18, |_| {});
}

/// c += x * b over the full slices (unit stride; auto-vectorized).
#[inline]
fn axpy1(c: &mut [f32], x: f32, b: &[f32]) {
    if x == 0.0 {
        return; // ReLU activations are ~50% zeros
    }
    for (cv, &bv) in c.iter_mut().zip(b) {
        *cv += x * bv;
    }
}

/// Four C rows advance together against one B row: b is loaded once per
/// lane instead of four times. The re-slicing to a common length lets LLVM
/// drop every bounds check and vectorize the fused loop.
#[inline]
fn axpy4(c0: &mut [f32], c1: &mut [f32], c2: &mut [f32], c3: &mut [f32], xs: [f32; 4], b: &[f32]) {
    let n = b.len();
    let (c0, c1, c2, c3) = (&mut c0[..n], &mut c1[..n], &mut c2[..n], &mut c3[..n]);
    for j in 0..n {
        let bv = b[j];
        c0[j] += xs[0] * bv;
        c1[j] += xs[1] * bv;
        c2[j] += xs[2] * bv;
        c3[j] += xs[3] * bv;
    }
}

/// The shared micro-kernel: chunk (rows x n, contiguous, pre-zeroed or
/// mid-accumulation) += panel (rows x k, contiguous row-major) * b (k x n
/// row-major). K-blocked; row quads share each streamed B row.
fn gemm_strip(chunk: &mut [f32], panel: &[f32], rows: usize, k: usize, n: usize, bd: &[f32]) {
    debug_assert!(chunk.len() >= rows * n);
    debug_assert!(panel.len() >= rows * k);
    debug_assert!(bd.len() >= k * n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + KC).min(k);
        let mut r = 0;
        while r + 4 <= rows {
            let quad = &mut chunk[r * n..(r + 4) * n];
            let (c0, rest) = quad.split_at_mut(n);
            let (c1, rest) = rest.split_at_mut(n);
            let (c2, c3) = rest.split_at_mut(n);
            let a0 = &panel[r * k + k0..r * k + k1];
            let a1 = &panel[(r + 1) * k + k0..(r + 1) * k + k1];
            let a2 = &panel[(r + 2) * k + k0..(r + 2) * k + k1];
            let a3 = &panel[(r + 3) * k + k0..(r + 3) * k + k1];
            for (off, ((&x0, &x1), (&x2, &x3))) in
                a0.iter().zip(a1).zip(a2.iter().zip(a3)).enumerate()
            {
                let xs = [x0, x1, x2, x3];
                if xs == [0.0f32; 4] {
                    continue;
                }
                let kk = k0 + off;
                axpy4(c0, c1, c2, c3, xs, &bd[kk * n..kk * n + n]);
            }
            r += 4;
        }
        while r < rows {
            let crow = &mut chunk[r * n..(r + 1) * n];
            for kk in k0..k1 {
                axpy1(crow, panel[r * k + kk], &bd[kk * n..kk * n + n]);
            }
            r += 1;
        }
        k0 = k1;
    }
}

/// C = A B.  A: (m,k), B: (k,n) -> (m,n).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut out);
    out
}

/// C = A B into a caller-owned (m,n) output (contents overwritten).
pub fn matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let _s = span("gemm-nn");
    let (m, k) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul inner dim: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(out.shape(), (m, n), "matmul output shape");
    let flops = 2 * m * k * n;
    let ad = a.data();
    let bd = b.data();
    parallel_rows_mut(out.data_mut(), n, min_rows_for(m, flops), |start, chunk| {
        let rows = chunk.len() / n;
        chunk.fill(0.0);
        gemm_strip(chunk, &ad[start * k..(start + rows) * k], rows, k, n, bd);
    });
}

/// C = Aᵀ B.  A: (k,m), B: (k,n) -> (m,n).  The gradient outer product:
/// k is the (small) batch dimension, m/n are layer widths.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.cols(), b.cols());
    matmul_tn_into(a, b, &mut out);
    out
}

/// C = Aᵀ B into a caller-owned (m,n) output. Each output strip packs its
/// thin (rows x k) slice of Aᵀ into per-thread scratch — k is the batch
/// dimension, so the pack is a vanishing fraction of the 2mkn FLOPs — and
/// then runs the contiguous strip kernel.
pub fn matmul_tn_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let _s = span("gemm-tn");
    let (k, m) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "matmul_tn inner dim: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(out.shape(), (m, n), "matmul_tn output shape");
    let flops = 2 * m * k * n;
    let ad = a.data();
    let bd = b.data();
    parallel_rows_mut(out.data_mut(), n, min_rows_for(m, flops), |start, chunk| {
        let rows = chunk.len() / n;
        chunk.fill(0.0);
        with_scratch(rows * k, |pack| {
            for kk in 0..k {
                let acol = &ad[kk * m + start..kk * m + start + rows];
                for (r, &v) in acol.iter().enumerate() {
                    pack[r * k + kk] = v;
                }
            }
            gemm_strip(chunk, pack, rows, k, n, bd);
        });
    });
}

/// C = A Bᵀ.  A: (m,k), B: (n,k) -> (m,n).  The backward delta contraction.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(a.rows(), b.rows());
    matmul_nt_into(a, b, &mut out);
    out
}

/// C = A Bᵀ into a caller-owned (m,n) output.
///
/// Parallelized over *column* blocks: each task packs its own Bᵀ panel
/// (k x jb) on the fly into per-thread scratch and accumulates a contiguous
/// (m x jb) sub-result with the strip kernel, then scatters it into the
/// output columns. This replaces the seed's two regimes (a dot-product
/// kernel that stalled on horizontal adds, and a transpose-the-whole-B
/// fallback that allocated an n x k temporary per call) with one
/// allocation-free path whose packing cost is O(nk) against 2mnk FLOPs.
pub fn matmul_nt_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let _s = span("gemm-nt");
    let (m, k) = a.shape();
    let (n, k2) = b.shape();
    assert_eq!(k, k2, "matmul_nt inner dim: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(out.shape(), (m, n), "matmul_nt output shape");
    let flops = 2 * m * k * n;
    let ad = a.data();
    let bd = b.data();
    let jb = if flops < PAR_FLOP_THRESHOLD {
        n.max(1)
    } else {
        let chunks = parallel::num_threads().min(n.div_ceil(MIN_COLS)).max(1);
        n.div_ceil(chunks).clamp(MIN_COLS.min(n.max(1)), MAX_COLS)
    };
    let out_base = out.data_mut().as_mut_ptr() as usize;
    super::pool::run(n.div_ceil(jb), &|c| {
        let j0 = c * jb;
        let j1 = ((c + 1) * jb).min(n);
        if j0 >= j1 {
            return;
        }
        let w = j1 - j0;
        with_scratch(k * w + m * w, |scr| {
            let (bt, csub) = scr.split_at_mut(k * w);
            // Pack the Bᵀ panel: bt[kk][jj] = B[j0 + jj][kk].
            for jj in 0..w {
                let brow = &bd[(j0 + jj) * k..(j0 + jj + 1) * k];
                for (kk, &v) in brow.iter().enumerate() {
                    bt[kk * w + jj] = v;
                }
            }
            csub.fill(0.0);
            gemm_strip(csub, ad, m, k, w, bt);
            // Scatter the contiguous sub-result into the output columns.
            for i in 0..m {
                // SAFETY: tasks own disjoint column ranges [j0, j1) of each
                // row, so these slices never overlap across tasks, stay in
                // bounds (j1 <= n), and `out`'s borrow outlives the
                // blocking pool::run call.
                let dst = unsafe {
                    std::slice::from_raw_parts_mut((out_base as *mut f32).add(i * n + j0), w)
                };
                dst.copy_from_slice(&csub[i * w..(i + 1) * w]);
            }
        });
    });
}

/// Unit-stride dot product with 8-lane unrolled accumulators.
///
/// chunks_exact + zip lets LLVM elide every bounds check and vectorize;
/// the indexed version of this loop ran at ~2.5 GFLOP/s inside the seed's
/// matmul_nt, this one at ~9 GFLOP/s (EXPERIMENTS.md §Perf, L3 iteration 1).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let rx = xc.remainder();
    let ry = yc.remainder();
    for (xs, ys) in xc.zip(yc) {
        for l in 0..8 {
            acc[l] += xs[l] * ys[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for (a, b) in rx.iter().zip(ry) {
        s += a * b;
    }
    s
}

/// y = A x.  A: (m,n), x: n -> m.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; a.rows()];
    matvec_into(a, x, &mut out);
    out
}

/// y = A x into a caller-owned length-m buffer (overwritten).
pub fn matvec_into(a: &Matrix, x: &[f32], out: &mut [f32]) {
    let (m, n) = a.shape();
    assert_eq!(x.len(), n);
    assert_eq!(out.len(), m);
    for (i, o) in out.iter_mut().enumerate() {
        *o = dot(a.row(i), x);
    }
}

/// y = Aᵀ x.  A: (m,n), x: m -> n.
pub fn matvec_t(a: &Matrix, x: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; a.cols()];
    matvec_t_into(a, x, &mut out);
    out
}

/// y = Aᵀ x into a caller-owned length-n buffer (overwritten).
pub fn matvec_t_into(a: &Matrix, x: &[f32], out: &mut [f32]) {
    let (m, n) = a.shape();
    assert_eq!(x.len(), m);
    assert_eq!(out.len(), n);
    out.fill(0.0);
    for i in 0..m {
        axpy1(out, x[i], a.row(i));
    }
}

/// Naive triple-loop oracle (tests + perf baseline).
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (_, n) = b.shape();
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for kk in 0..k {
                s += a[(i, kk)] * b[(kk, j)];
            }
            out[(i, j)] = s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!(a.shape(), b.shape());
        let d = a.max_abs_diff(b);
        assert!(d < tol, "max abs diff {d} >= {tol}");
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (32, 784, 64), (17, 13, 29), (5, 300, 9)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-3);
        }
    }

    #[test]
    fn tn_equals_transpose_then_mul() {
        let mut rng = Rng::new(2);
        for &(k, m, n) in &[(8, 33, 21), (32, 128, 64), (1, 5, 5), (300, 7, 13)] {
            let a = Matrix::randn(k, m, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            close(&matmul_tn(&a, &b), &matmul(&a.transpose(), &b), 1e-3);
        }
    }

    #[test]
    fn nt_equals_mul_transpose() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(9, 17, 5), (32, 64, 128), (1, 1, 1), (6, 500, 37)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(n, k, 1.0, &mut rng);
            close(&matmul_nt(&a, &b), &matmul(&a, &b.transpose()), 1e-3);
        }
    }

    #[test]
    fn big_parallel_path_correct() {
        // Force the threaded path (flops > threshold) and compare to naive.
        let mut rng = Rng::new(4);
        let a = Matrix::randn(256, 300, 1.0, &mut rng);
        let b = Matrix::randn(300, 256, 1.0, &mut rng);
        close(&matmul(&a, &b), &matmul_naive(&a, &b), 1e-2);
        // Threaded transposed variants on the same scale.
        let c = matmul_nt(&a, &b.transpose());
        close(&c, &matmul_naive(&a, &b), 1e-2);
        let d = matmul_tn(&a, &a);
        close(&d, &matmul_naive(&a.transpose(), &a), 1e-2);
    }

    #[test]
    fn into_variants_overwrite_dirty_buffers() {
        // Workspace reuse hands kernels dirty outputs; results must be
        // identical to the fresh-allocation path, bit for bit.
        let mut rng = Rng::new(9);
        let a = Matrix::randn(13, 40, 1.0, &mut rng);
        let b = Matrix::randn(40, 11, 1.0, &mut rng);
        let fresh = matmul(&a, &b);
        let mut dirty = Matrix::filled(13, 11, f32::from_bits(0x7f7f_7f7f));
        matmul_into(&a, &b, &mut dirty);
        assert_eq!(fresh, dirty);

        let fresh_tn = matmul_tn(&b, &b);
        let mut dirty_tn = Matrix::filled(11, 11, -3.0);
        matmul_tn_into(&b, &b, &mut dirty_tn);
        assert_eq!(fresh_tn, dirty_tn);

        let fresh_nt = matmul_nt(&a, &a);
        let mut dirty_nt = Matrix::filled(13, 13, 42.0);
        matmul_nt_into(&a, &a, &mut dirty_nt);
        assert_eq!(fresh_nt, dirty_nt);
    }

    #[test]
    fn matvec_consistency() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(20, 30, 1.0, &mut rng);
        let x: Vec<f32> = (0..30).map(|i| (i as f32).sin()).collect();
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(30, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..20 {
            assert!((y[i] - ym[(i, 0)]).abs() < 1e-4);
        }
        let z = matvec_t(&a, &y);
        let zm = matmul_tn(&a, &ym);
        for j in 0..30 {
            assert!((z[j] - zm[(j, 0)]).abs() < 1e-3);
        }
        // Into-variants agree with the allocating ones on dirty buffers.
        let mut y2 = vec![7.0f32; 20];
        matvec_into(&a, &x, &mut y2);
        assert_eq!(y, y2);
        let mut z2 = vec![-1.0f32; 30];
        matvec_t_into(&a, &y, &mut z2);
        assert_eq!(z, z2);
    }

    #[test]
    fn dot_handles_tails() {
        let x: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..7).map(|i| (i + 1) as f32).collect();
        // 0*1+1*2+2*3+3*4+4*5+5*6+6*7 = 112
        assert_eq!(dot(&x, &y), 112.0);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(12, 12, 1.0, &mut rng);
        close(&matmul(&a, &Matrix::identity(12)), &a, 1e-5);
        close(&matmul(&Matrix::identity(12), &a), &a, 1e-5);
    }
}

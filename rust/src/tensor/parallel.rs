//! Data-parallel helpers over the persistent worker pool (`pool`): row- and
//! range-chunked execution with a FLOP-threshold escape hatch decided by the
//! callers — small matmuls dominate the per-batch hot path, and even the
//! pool's wake/park handshake is not free.
//!
//! (The seed's scoped-thread implementation — and its duplicated row-count
//! clamp — lives on only in benches/hotpath.rs as the "legacy" baseline the
//! §Perf numbers in EXPERIMENTS.md are measured against.)

use super::pool;

/// Number of worker lanes (pool width including the calling thread);
/// override with DAD_THREADS before first use, or `pool::shutdown()` and
/// set it to re-size mid-process.
pub fn num_threads() -> usize {
    pool::num_threads()
}

/// Run `f(lo, hi)` over disjoint chunks of 0..n, possibly in parallel.
/// `f` must be safe to run concurrently on disjoint ranges. At most
/// `num_threads()` chunks are created, and never smaller than `min_chunk`
/// (so callers can force the serial path by passing `min_chunk >= n`).
pub fn parallel_ranges<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    // Resolve the chunk cap before touching the pool, so serial-only calls
    // (n below min_chunk) never force pool initialization.
    let max_chunks = n.div_ceil(min_chunk.max(1));
    let chunks = if max_chunks <= 1 { 1 } else { num_threads().min(max_chunks).max(1) };
    if chunks == 1 {
        f(0, n);
        return;
    }
    let per = n.div_ceil(chunks);
    pool::run(n.div_ceil(per), &|c| {
        let lo = c * per;
        let hi = ((c + 1) * per).min(n);
        if lo < hi {
            f(lo, hi);
        }
    });
}

/// Split a mutable slice into disjoint row-chunks and run `f(first_row,
/// rows)` on each in parallel. `row_len` is the stride; chunk boundaries
/// are row-aligned. Rows are `data.len() / row_len`; any trailing partial
/// row is ignored in the parallel path and included in the serial one
/// (matching the historical contract relied on by `ops`).
pub fn parallel_rows_mut<F>(data: &mut [f32], row_len: usize, min_rows: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    if rows == 0 {
        return;
    }
    let max_chunks = rows.div_ceil(min_rows.max(1));
    let chunks = if max_chunks <= 1 { 1 } else { num_threads().min(max_chunks).max(1) };
    if chunks == 1 {
        f(0, data);
        return;
    }
    let per = rows.div_ceil(chunks);
    let base = data.as_mut_ptr() as usize;
    pool::run(rows.div_ceil(per), &|c| {
        let lo = c * per;
        let hi = ((c + 1) * per).min(rows);
        if lo >= hi {
            return;
        }
        // SAFETY: jobs partition 0..rows into disjoint row ranges, so these
        // reconstructed sub-slices never overlap, stay inside the `data`
        // borrow (hi <= rows, rows * row_len <= data.len()), and `data`
        // outlives the blocking pool::run call.
        let chunk = unsafe {
            std::slice::from_raw_parts_mut((base as *mut f32).add(lo * row_len), (hi - lo) * row_len)
        };
        f(lo, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(1000, 10, |lo, hi| {
            hits.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn small_stays_serial() {
        // n below min_chunk => single call covering everything.
        let calls = AtomicUsize::new(0);
        parallel_ranges(5, 100, |lo, hi| {
            calls.fetch_add(1, Ordering::SeqCst);
            assert_eq!((lo, hi), (0, 5));
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn rows_mut_disjoint_and_complete() {
        let mut data = vec![0.0f32; 64 * 8];
        parallel_rows_mut(&mut data, 8, 4, |start, chunk| {
            for (r, row) in chunk.chunks_mut(8).enumerate() {
                for v in row.iter_mut() {
                    *v = (start + r) as f32;
                }
            }
        });
        for r in 0..64 {
            for c in 0..8 {
                assert_eq!(data[r * 8 + c], r as f32);
            }
        }
    }

    #[test]
    fn rows_mut_uneven_chunks() {
        // 37 rows, min 3: chunk math must cover every row exactly once.
        let mut data = vec![-1.0f32; 37 * 5];
        parallel_rows_mut(&mut data, 5, 3, |start, chunk| {
            for (r, row) in chunk.chunks_mut(5).enumerate() {
                row.fill((start + r) as f32);
            }
        });
        for r in 0..37 {
            assert_eq!(data[r * 5], r as f32, "row {r}");
        }
    }
}

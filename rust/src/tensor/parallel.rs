//! Minimal data-parallel substrate (no rayon offline): scoped threads over
//! row-range chunks, with a FLOP threshold below which work stays on the
//! calling thread — small matmuls dominate the per-batch hot path and thread
//! spawn overhead would swamp them.

use std::sync::OnceLock;

/// Number of worker threads; override with DAD_THREADS.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("DAD_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
    })
}

/// Run `f(lo, hi)` over disjoint chunks of 0..n, possibly in parallel.
/// `f` must be safe to run concurrently on disjoint ranges.
pub fn parallel_ranges<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nt = num_threads();
    if n == 0 {
        return;
    }
    let chunks = nt.min(n.div_ceil(min_chunk.max(1))).max(1);
    if chunks == 1 {
        f(0, n);
        return;
    }
    let per = n.div_ceil(chunks);
    std::thread::scope(|s| {
        for c in 0..chunks {
            let lo = c * per;
            let hi = ((c + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Split a mutable slice into disjoint row-chunks and run `f` on each in
/// parallel. `row_len` is the stride; chunk boundaries are row-aligned.
pub fn parallel_rows_mut<F>(data: &mut [f32], row_len: usize, min_rows: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { data.len() / row_len };
    if rows == 0 {
        return;
    }
    let nt = num_threads();
    let chunks = nt.min(rows.div_ceil(min_rows.max(1))).max(1);
    if chunks == 1 {
        f(0, data);
        return;
    }
    let per = rows.div_ceil(chunks);
    std::thread::scope(|s| {
        let mut rest = data;
        let mut row0 = 0usize;
        for _ in 0..chunks {
            let take = per.min(rest.len() / row_len - 0);
            if take == 0 {
                break;
            }
            let take = take.min(rest.len() / row_len);
            let (head, tail) = rest.split_at_mut(take * row_len);
            rest = tail;
            let f = &f;
            let start = row0;
            s.spawn(move || f(start, head));
            row0 += take;
            if rest.is_empty() {
                break;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn covers_all_indices() {
        let hits = AtomicUsize::new(0);
        parallel_ranges(1000, 10, |lo, hi| {
            hits.fetch_add(hi - lo, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn small_stays_serial() {
        // n below min_chunk => single call covering everything.
        let calls = AtomicUsize::new(0);
        parallel_ranges(5, 100, |lo, hi| {
            calls.fetch_add(1, Ordering::SeqCst);
            assert_eq!((lo, hi), (0, 5));
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn rows_mut_disjoint_and_complete() {
        let mut data = vec![0.0f32; 64 * 8];
        parallel_rows_mut(&mut data, 8, 4, |start, chunk| {
            for (r, row) in chunk.chunks_mut(8).enumerate() {
                for v in row.iter_mut() {
                    *v = (start + r) as f32;
                }
            }
        });
        for r in 0..64 {
            for c in 0..8 {
                assert_eq!(data[r * 8 + c], r as f32);
            }
        }
    }
}

//! Persistent worker pool — the dispatch substrate under every parallel
//! kernel.
//!
//! The seed engine spawned fresh scoped OS threads per matmul; at the
//! paper's shapes (batch 32-64) thread creation dominated the kernels
//! themselves. This pool parks its workers on a condvar and hands them
//! jobs through a single shared chunk counter, so per-call dispatch is one
//! mutex/condvar handshake (~µs) and **zero heap allocations** — a property
//! the steady-state training step relies on (tests/alloc_free.rs).
//!
//! Design:
//!   - One global pool, lazily created on first use; width comes from
//!     DAD_THREADS (re-read on every (re)initialization) or the machine's
//!     available parallelism, capped at 16.
//!   - A job is a borrowed closure `f(chunk_index)` plus a chunk count.
//!     Workers (and the calling thread) claim chunk indices off an atomic
//!     counter until exhausted — natural load balancing, no per-chunk
//!     queue nodes.
//!   - `run` blocks until every claimed chunk has finished, which is what
//!     makes lending a stack-borrowed closure to the workers sound.
//!   - Worker panics are caught and re-raised on the caller; the pool
//!     itself stays usable.
//!   - `shutdown` parks nothing: it joins all workers and clears the
//!     global handle; the next `run`/`num_threads` re-initializes (used by
//!     tests to vary DAD_THREADS within one process).

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Type-erased pointer to the caller's chunk closure. Only ever
/// dereferenced while the posting `run` call is blocked, which keeps the
/// borrow alive.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (bound enforced by `run`'s signature) and
// outlives every dereference because `run` does not return until all
// workers have retired the job.
unsafe impl Send for JobPtr {}

struct State {
    /// Bumped once per posted job; workers use it to detect new work.
    epoch: u64,
    /// Live job, present from post until retire.
    job: Option<JobPtr>,
    n_chunks: usize,
    /// Workers currently executing the live job.
    active: usize,
    /// A worker panicked while executing the live job.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here waiting for a new epoch.
    work_cv: Condvar,
    /// The caller parks here waiting for `active` to drain.
    done_cv: Condvar,
    /// Next unclaimed chunk index of the live job.
    next_chunk: AtomicUsize,
    /// Pool width including the calling thread.
    width: usize,
}

struct Pool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    fn spawn(width: usize) -> Pool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                n_chunks: 0,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next_chunk: AtomicUsize::new(0),
            width,
        });
        let handles = (1..width)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("dad-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool { shared, handles }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen_epoch = 0u64;
    // Warm the kernel packing scratch now, while nobody is timing or
    // counting allocations (see ops::prewarm_scratch).
    super::ops::prewarm_scratch();
    // True while this thread executes pool chunks: nested parallel calls
    // from inside a kernel run inline instead of deadlocking on the pool.
    IN_POOL.with(|b| b.set(true));
    loop {
        // Park until a new job (or shutdown) shows up, then join it.
        let (ptr, n_chunks) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    seen_epoch = st.epoch;
                    if let Some(JobPtr(p)) = st.job {
                        st.active += 1;
                        break (p, st.n_chunks);
                    }
                    // Job already retired before this worker woke; keep
                    // waiting for the next epoch.
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: see JobPtr — the posting caller is blocked until we
        // decrement `active`, so the closure borrow is alive.
        let f = unsafe { &*ptr };
        let result = catch_unwind(AssertUnwindSafe(|| loop {
            let c = shared.next_chunk.fetch_add(1, Ordering::Relaxed);
            if c >= n_chunks {
                break;
            }
            f(c);
        }));
        let mut st = shared.state.lock().unwrap();
        if result.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

thread_local! {
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

static REGISTRY: OnceLock<Mutex<Option<Pool>>> = OnceLock::new();

fn registry() -> &'static Mutex<Option<Pool>> {
    REGISTRY.get_or_init(|| Mutex::new(None))
}

/// Thread count the next pool initialization will use: DAD_THREADS
/// (clamped to [1, 64]) or available parallelism capped at 16.
fn configured_threads() -> usize {
    if let Ok(v) = std::env::var("DAD_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

fn handle() -> Arc<Shared> {
    let mut reg = registry().lock().unwrap();
    if reg.is_none() {
        *reg = Some(Pool::spawn(configured_threads()));
    }
    Arc::clone(&reg.as_ref().unwrap().shared)
}

/// Current pool width (callers + workers), initializing the pool if needed.
pub fn num_threads() -> usize {
    handle().width
}

/// Join all workers and drop the global pool. The next `run` or
/// `num_threads` call re-initializes, re-reading DAD_THREADS — which is how
/// tests sweep thread counts inside one process. Must not be called from
/// inside a pool job.
pub fn shutdown() {
    let pool = registry().lock().unwrap().take();
    if let Some(pool) = pool {
        {
            let mut st = pool.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        pool.shared.work_cv.notify_all();
        for h in pool.handles {
            let _ = h.join();
        }
    }
}

/// Execute `f(0), f(1), .., f(n_chunks - 1)` across the pool (the calling
/// thread participates), returning when all chunks are done. Chunks must be
/// safe to run concurrently. Allocation-free after pool initialization.
pub fn run(n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
    if n_chunks == 0 {
        return;
    }
    // Serial fast paths: trivial jobs, nested calls from inside a pool
    // chunk (the pool's single job slot cannot express recursion), or a
    // width-1 pool.
    if n_chunks == 1 || IN_POOL.with(|b| b.get()) {
        for c in 0..n_chunks {
            f(c);
        }
        return;
    }
    let shared = handle();
    if shared.width <= 1 {
        for c in 0..n_chunks {
            f(c);
        }
        return;
    }
    // Post the job. The state mutex doubles as the job slot: `run` holds no
    // other lock, and concurrent top-level `run` calls serialize on the
    // post/retire protocol below (a second poster would observe
    // `job.is_some()` and spin-wait on done_cv via the retire path of the
    // first — prevented instead by taking the slot under the same lock).
    {
        let mut st = shared.state.lock().unwrap();
        while st.job.is_some() {
            // Another thread's job is in flight; wait for it to retire.
            st = shared.done_cv.wait(st).unwrap();
        }
        shared.next_chunk.store(0, Ordering::Relaxed);
        st.job = Some(JobPtr(f as *const (dyn Fn(usize) + Sync)));
        st.n_chunks = n_chunks;
        st.epoch = st.epoch.wrapping_add(1);
        shared.work_cv.notify_all();
    }
    // Participate in our own job.
    IN_POOL.with(|b| b.set(true));
    let result = catch_unwind(AssertUnwindSafe(|| loop {
        let c = shared.next_chunk.fetch_add(1, Ordering::Relaxed);
        if c >= n_chunks {
            break;
        }
        f(c);
    }));
    IN_POOL.with(|b| b.set(false));
    // Retire: wait for joined workers to drain, clear the slot.
    let panicked = {
        let mut st = shared.state.lock().unwrap();
        while st.active > 0 {
            st = shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        let p = st.panicked;
        st.panicked = false;
        // Wake any poster waiting for the slot.
        shared.done_cv.notify_all();
        p
    };
    if let Err(payload) = result {
        resume_unwind(payload);
    }
    if panicked {
        panic!("pool worker panicked during parallel execution");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn covers_every_chunk_exactly_once() {
        let n = 257;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        run(n, &|c| {
            hits[c].fetch_add(1, Ordering::SeqCst);
        });
        for (c, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "chunk {c}");
        }
    }

    #[test]
    fn nested_runs_execute_inline() {
        let total = AtomicUsize::new(0);
        run(4, &|_| {
            // Nested: must run inline on this thread without deadlock.
            run(8, &|_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn reusable_across_many_jobs() {
        let total = AtomicUsize::new(0);
        for _ in 0..100 {
            run(16, &|_| {
                total.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(total.load(Ordering::SeqCst), 1600);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let caught = std::panic::catch_unwind(|| {
            run(64, &|c| {
                if c == 13 {
                    panic!("boom");
                }
            });
        });
        assert!(caught.is_err());
        // Pool still works afterwards.
        let total = AtomicUsize::new(0);
        run(8, &|_| {
            total.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(total.load(Ordering::SeqCst), 8);
    }
}

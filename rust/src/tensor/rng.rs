//! Deterministic PRNG substrate (PCG-XSH-RR 64/32).
//!
//! No external `rand` crate is available offline, and determinism across the
//! simulated cluster matters: the paper initializes every site "with the same
//! random seed", and our equivalence tests rely on bit-identical replicas.

/// PCG-XSH-RR 64/32 — small, fast, statistically solid, reproducible.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f32>,
}

impl Rng {
    /// Seeded constructor; `stream` selects an independent sequence.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1, gauss_spare: None };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seeded constructor on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Snapshot the full generator state `(state, inc, gauss_spare)` for
    /// checkpointing. [`Rng::from_parts`] restores an identical generator.
    pub fn state_parts(&self) -> (u64, u64, Option<f32>) {
        (self.state, self.inc, self.gauss_spare)
    }

    /// Rebuild a generator from a [`Rng::state_parts`] snapshot; the restored
    /// generator continues the exact output sequence of the original.
    pub fn from_parts(state: u64, inc: u64, gauss_spare: Option<f32>) -> Self {
        Rng { state, inc, gauss_spare }
    }

    /// Derive an independent child generator (used to give each simulated
    /// site / data shard its own stream while staying reproducible).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15);
        Rng::with_stream(seed, tag.wrapping_add(1))
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two 32-bit draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for simulation purposes.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * core::f32::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Rng::new(7);
        let mut sum = 0.0f64;
        for _ in 0..20_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = rng.normal() as f64;
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_parts_roundtrip_continues_sequence() {
        let mut a = Rng::new(17);
        // Burn a mix of draw kinds, leaving a cached Box-Muller spare.
        for _ in 0..7 {
            a.next_u64();
            a.normal();
        }
        let (state, inc, spare) = a.state_parts();
        let mut b = Rng::from_parts(state, inc, spare);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
            assert_eq!(a.normal().to_bits(), b.normal().to_bits());
        }
    }

    #[test]
    fn fork_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}

//! Step workspace: a recycling arena for the training hot path.
//!
//! A steady-state training step computes the same set of activation, delta
//! and scratch matrices every batch. The seed engine re-allocated all of
//! them per step; this arena lends out `Matrix`/`Vec<f32>` buffers and
//! takes them back, so after the first (warm-up) step the entire
//! `local_stats` pipeline performs **zero heap allocations** — asserted by
//! a counting-allocator test (tests/alloc_free.rs).
//!
//! The design deliberately reuses the existing `Matrix` type instead of
//! introducing views: `take` hands out a real `Matrix` built from a pooled
//! `Vec<f32>` (resized in place, no realloc once warm), and `recycle`
//! reclaims its storage. Buffers are matched best-fit by capacity so a
//! fixed shape-set reaches a fixed buffer-set. Lists of matrices
//! (activation stacks) recycle the same way via `take_list`/`recycle_list`.

use super::matrix::Matrix;

/// Recycling buffer arena. Cheap to construct (no allocation until first
/// use); hold one per site/thread and reuse it across steps.
#[derive(Default)]
pub struct Workspace {
    /// Reclaimed f32 buffers, kept sorted ascending by capacity so
    /// `take` can bisect for the best fit.
    bufs: Vec<Vec<f32>>,
    /// Reclaimed matrix-list containers (emptied before storage).
    lists: Vec<Vec<Matrix>>,
}

impl Workspace {
    /// Empty workspace (buffers accrete through `recycle`).
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Number of parked buffers (diagnostics/tests).
    pub fn parked(&self) -> usize {
        self.bufs.len()
    }

    /// Smallest parked buffer with capacity >= n, else the largest parked
    /// buffer (which will grow once and then fit forever), else a new one.
    fn take_buf(&mut self, n: usize) -> Vec<f32> {
        if self.bufs.is_empty() {
            return Vec::with_capacity(n);
        }
        let idx = match self.bufs.partition_point(|b| b.capacity() < n) {
            i if i < self.bufs.len() => i,          // best fit
            _ => self.bufs.len() - 1,               // largest; will grow
        };
        self.bufs.remove(idx)
    }

    /// Park a raw buffer for reuse.
    pub fn recycle_vec(&mut self, mut v: Vec<f32>) {
        v.clear();
        let at = self.bufs.partition_point(|b| b.capacity() < v.capacity());
        self.bufs.insert(at, v);
    }

    /// Park a matrix's storage for reuse.
    pub fn recycle(&mut self, m: Matrix) {
        self.recycle_vec(m.into_vec());
    }

    /// A zeroed (rows, cols) matrix backed by a recycled buffer.
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        let n = rows * cols;
        let mut buf = self.take_buf(n);
        buf.clear();
        buf.resize(n, 0.0);
        Matrix::from_vec(rows, cols, buf)
    }

    /// A zeroed length-n vector backed by a recycled buffer.
    pub fn take_vec(&mut self, n: usize) -> Vec<f32> {
        let mut buf = self.take_buf(n);
        buf.clear();
        buf.resize(n, 0.0);
        buf
    }

    /// A recycled copy of `src` (same shape and contents).
    pub fn copy_in(&mut self, src: &Matrix) -> Matrix {
        let mut m = self.take(src.rows(), src.cols());
        m.data_mut().copy_from_slice(src.data());
        m
    }

    /// An empty `Vec<Matrix>` container with recycled capacity.
    pub fn take_list(&mut self) -> Vec<Matrix> {
        self.lists.pop().unwrap_or_default()
    }

    /// Park a matrix list: remaining matrices are recycled individually,
    /// the container's capacity is kept for `take_list`.
    pub fn recycle_list(&mut self, mut list: Vec<Matrix>) {
        for m in list.drain(..) {
            self.recycle(m);
        }
        self.lists.push(list);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_roundtrip_reuses_storage() {
        let mut ws = Workspace::new();
        let m = ws.take(4, 8);
        assert_eq!(m.shape(), (4, 8));
        assert!(m.data().iter().all(|&v| v == 0.0));
        let ptr = m.data().as_ptr();
        ws.recycle(m);
        assert_eq!(ws.parked(), 1);
        // Same-size take must reuse the parked buffer (same allocation).
        let m2 = ws.take(8, 4);
        assert_eq!(m2.data().as_ptr(), ptr);
        assert_eq!(ws.parked(), 0);
    }

    #[test]
    fn take_zeroes_recycled_contents() {
        let mut ws = Workspace::new();
        let mut m = ws.take(3, 3);
        m.data_mut().fill(7.5);
        ws.recycle(m);
        let m2 = ws.take(3, 3);
        assert!(m2.data().iter().all(|&v| v == 0.0));
        let v = ws.take_vec(9);
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let mut ws = Workspace::new();
        let small = ws.take(2, 2);
        let big = ws.take(10, 10);
        let small_ptr = small.data().as_ptr();
        ws.recycle(big);
        ws.recycle(small);
        // A 2x2 request must get the 4-capacity buffer, not the 100 one.
        let again = ws.take(2, 2);
        assert_eq!(again.data().as_ptr(), small_ptr);
    }

    #[test]
    fn copy_in_and_lists() {
        let mut ws = Workspace::new();
        let src = Matrix::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        let cp = ws.copy_in(&src);
        assert_eq!(cp, src);
        let mut list = ws.take_list();
        list.push(cp);
        list.push(ws.take(5, 5));
        ws.recycle_list(list);
        assert_eq!(ws.parked(), 2);
        let list2 = ws.take_list();
        assert!(list2.is_empty());
        assert!(list2.capacity() >= 2);
    }
}

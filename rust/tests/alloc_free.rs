//! Counting-allocator proof of the workspace contract: a steady-state MLP
//! `local_stats_into` step on a reused `Workspace` + `LocalStats` performs
//! ZERO heap allocations — forward activations, backward deltas, the loss
//! delta, kernel packing scratch and pool dispatch all run on recycled or
//! pre-warmed storage.
//!
//! Tracing is ENABLED for the measured region: the span hot path (GEMM
//! spans fire inside every `local_stats_into`, plus an explicit tagged
//! protocol-style span per iteration) must also be allocation-free once
//! the per-thread event buffer has been registered during warm-up —
//! JSONL formatting happens only at `flush`, outside the armed window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use dad::nn::loss::one_hot;
use dad::nn::model::{Batch, DistModel};
use dad::nn::stats::LocalStats;
use dad::nn::Mlp;
use dad::tensor::{Matrix, Rng, Workspace};

/// System allocator wrapped with an allocation counter that can be armed
/// around the measured region. Deallocations are free; only fresh
/// allocations (alloc/alloc_zeroed/growing realloc) count.
struct CountingAlloc;

static ARMED: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ARMED.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn mlp_local_stats_steady_state_is_allocation_free() {
    // Paper configuration: 784-1024-1024-10, batch 32/site — big enough to
    // exercise the threaded kernel paths (fc1/fc2 cross the FLOP
    // threshold), which is exactly where stray allocation would hide.
    let mut rng = Rng::new(1);
    let mlp = Mlp::paper_mnist(&mut rng);
    let x = Matrix::rand_uniform(32, 784, 0.0, 1.0, &mut rng);
    let labels: Vec<usize> = (0..32).map(|i| i % 10).collect();
    let batch = Batch::Dense { x, y: one_hot(&labels, 10) };

    // Arm tracing before warm-up: enable() opens the sink, and the first
    // span registers this thread's event buffer at full capacity — both
    // allocate, so they must happen outside the measured region.
    let trace_path =
        std::env::temp_dir().join(format!("dad-alloc-free-{}.jsonl", std::process::id()));
    dad::obs::trace::enable(&trace_path).expect("arming trace sink");

    let mut ws = Workspace::new();
    let mut out = LocalStats::empty();
    // Warm-up: spawns the pool (workers pre-size their packing scratch at
    // spawn), grows the workspace to its high-water mark, and settles the
    // container capacities (including the trace buffer).
    for _ in 0..5 {
        let _s = dad::obs::trace::tagged_span("round-up", "acts", dad::obs::trace::Phase::Comms);
        mlp.local_stats_into(&batch, &mut ws, &mut out);
    }

    ALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    for _ in 0..10 {
        let _s = dad::obs::trace::tagged_span("round-up", "acts", dad::obs::trace::Phase::Comms);
        mlp.local_stats_into(&batch, &mut ws, &mut out);
    }
    ARMED.store(false, Ordering::SeqCst);
    let n = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        n, 0,
        "steady-state local_stats (with tracing enabled) made {n} heap allocations (want 0)"
    );

    // The armed spans really were recorded: sealing the trace writes the
    // GEMM and round events gathered above.
    dad::obs::trace::finish().expect("sealing trace");
    let trace = std::fs::read_to_string(&trace_path).expect("trace file exists");
    assert!(trace.contains("\"name\":\"round-up\""), "tagged span missing from trace");
    assert!(trace.contains("\"name\":\"gemm-"), "gemm spans missing from trace");
    std::fs::remove_file(&trace_path).ok();

    // Sanity: the measured loop actually computed real statistics.
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert_eq!(out.entries.len(), 3);
    assert_eq!(out.entries[0].a.shape(), (32, 784));
    assert_eq!(out.entries[2].d.shape(), (32, 10));

    // Control: the allocating convenience path must trip the counter, so
    // a broken counter can't green-light the assertion above.
    ALLOCS.store(0, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    let fresh = mlp.local_stats(&batch);
    ARMED.store(false, Ordering::SeqCst);
    assert!(ALLOCS.load(Ordering::SeqCst) > 0, "counter failed to observe allocations");
    assert_eq!(fresh.loss.to_bits(), out.loss.to_bits(), "paths must agree bit-for-bit");
}

//! Convergence-or-clean-failure over the whole chaos-recipe registry:
//! every named scenario runs end-to-end over real localhost sockets and
//! must land exactly on its declared expectation — completion with all
//! sites, completion degraded to the declared survivor count, or a clean
//! `io::Error` naming the cause. No recipe may hang or panic.
//!
//! Also covered here: `--strict` turning a degradable loss into a clean
//! failure that names the lost site, and end-to-end chaos determinism —
//! two same-seed runs of a fault recipe produce identical loss
//! trajectories, byte counts and survivor schedules.

use dad::scenario::{find_recipe, named_recipes, run_recipe, Expectation, RecipeReport};

fn run_checked(name: &str) -> RecipeReport {
    let recipe = find_recipe(name).unwrap_or_else(|| panic!("recipe {name} not in registry"));
    let report = run_recipe(&recipe, false);
    if let Err(msg) = report.check(&recipe) {
        panic!(
            "{msg}\n  aggregator error: {:?}\n  site errors: {:?}",
            report.error, report.site_errors
        );
    }
    report
}

/// Fault-free and delay-only recipes complete with every site alive; the
/// partition-skew recipe additionally proves uneven shards keep lockstep.
#[test]
fn converge_recipes_complete_with_all_sites() {
    for name in ["clean-dad", "slow-link-dad", "slow-link-rank-dad", "skew-quantity-dad"] {
        let report = run_checked(name);
        assert!(
            report.site_errors.is_empty(),
            "{name}: healthy run had site errors: {:?}",
            report.site_errors
        );
    }
}

/// A site disconnecting at a step boundary degrades the run to the
/// survivors for every algorithm whose exchange follows the sync frame —
/// the ISSUE's mid-training disconnect acceptance criterion.
#[test]
fn mid_drop_recipes_degrade_to_survivors() {
    for name in ["mid-drop-dad", "mid-drop-dsgd", "mid-drop-rank-dad", "dgc-mid-drop"] {
        let report = run_checked(name);
        // The severed site reports its injected disconnect; survivors
        // finish without errors, so exactly one site errored.
        assert_eq!(
            report.site_errors.len(),
            1,
            "{name}: expected exactly the severed site to error: {:?}",
            report.site_errors
        );
        let (site, err) = &report.site_errors[0];
        assert_eq!(*site, 2, "{name}: wrong site was lost");
        assert!(err.contains("injected disconnect"), "{name}: {err}");
    }
}

/// A site stalling past the aggregator's straggler deadline is retired
/// and the run continues with the survivors.
#[test]
fn straggler_past_deadline_is_retired() {
    let report = run_checked("straggler-dad");
    assert!(
        report.site_errors.iter().any(|(site, _)| *site == 1),
        "the stalled site should have errored after retirement: {:?}",
        report.site_errors
    );
}

/// Non-recoverable faults fail cleanly — mid-exchange frame loss, a lost
/// site under an algorithm that cannot shrink its topology, and the two
/// documented edAD rejections (which fail before any socket opens).
#[test]
fn failure_recipes_fail_cleanly_with_named_cause() {
    for name in ["drop-uplink-dsgd", "mid-drop-dad-p2p", "edad-periodic-reject", "edad-lm-reject"] {
        let report = run_checked(name);
        assert!(report.log.is_none(), "{name}: a failing recipe must not produce metrics");
    }
    // The topology-bound failure must name the lost site and suggest the
    // degradable algorithms.
    let recipe = find_recipe("mid-drop-dad-p2p").unwrap();
    let report = run_recipe(&recipe, false);
    let err = report.error.expect("dad-p2p must fail on a lost site").to_string();
    assert!(err.contains("lost site 2"), "error must name the site: {err}");
    assert!(err.contains("rank-dad"), "error must point at degradable algorithms: {err}");
}

/// `--strict` converts a degradable site loss into a clean failure naming
/// the lost site — the run must not silently continue with survivors.
#[test]
fn strict_mode_fails_instead_of_degrading() {
    let recipe = find_recipe("mid-drop-dad").unwrap();
    assert_eq!(recipe.expect, Expectation::Degrade(2), "precondition");
    let report = run_recipe(&recipe, true);
    assert!(report.log.is_none(), "strict run must not complete");
    let err = report.error.expect("strict run must fail").to_string();
    assert!(err.contains("lost site 2"), "strict error must name the site: {err}");
    assert!(err.contains("strict mode"), "strict error must say why it failed: {err}");
}

/// End-to-end chaos determinism over real sockets: two runs of the same
/// fault recipe produce identical loss trajectories, identical uplink /
/// downlink byte counts, and the identical survivor schedule.
#[test]
fn same_seed_fault_runs_are_identical() {
    let recipe = find_recipe("mid-drop-dad").unwrap();
    let a = run_recipe(&recipe, false).log.expect("run a");
    let b = run_recipe(&recipe, false).log.expect("run b");
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (e, (x, y)) in a.epochs.iter().zip(&b.epochs).enumerate() {
        assert_eq!(x.train_loss, y.train_loss, "epoch {e}: loss not reproducible");
        assert_eq!(x.bytes_up, y.bytes_up, "epoch {e}: uplink bytes not reproducible");
        assert_eq!(x.bytes_down, y.bytes_down, "epoch {e}: downlink bytes not reproducible");
        assert_eq!(x.sites_live, y.sites_live, "epoch {e}: survivor schedule not reproducible");
    }
    // The degrade happened mid-run, not at the start: epoch 0 already ran
    // with the survivors (the disconnect lands at step 3 of ~8), and the
    // CSV's sites_live column records it.
    assert_eq!(a.epochs.last().unwrap().sites_live, 2);
}

/// The time-domain half of the pure-delay contract: `slow-link-dad` is
/// `clean-dad` plus injected per-frame latency (same seed, same spec, no
/// drops or disconnects), so its losses and ledger byte counts must stay
/// byte-identical to the clean run while the injected seconds surface in
/// the aggregator's `stall_s`/`comms_s` phase breakdown — the wire got
/// slower, the math did not change.
#[test]
fn pure_delay_moves_seconds_not_bytes() {
    let clean = run_checked("clean-dad").log.expect("clean-dad log");
    let slow = run_checked("slow-link-dad").log.expect("slow-link-dad log");
    assert_eq!(clean.epochs.len(), slow.epochs.len());
    for (e, (c, s)) in clean.epochs.iter().zip(&slow.epochs).enumerate() {
        assert_eq!(c.train_loss, s.train_loss, "epoch {e}: delay changed the loss");
        assert_eq!(c.bytes_up, s.bytes_up, "epoch {e}: delay changed uplink bytes");
        assert_eq!(c.bytes_down, s.bytes_down, "epoch {e}: delay changed downlink bytes");
    }
    // Every epoch of the delayed run spends wall-clock blocked on the
    // paced links, and the run as a whole waits visibly longer than the
    // clean control: the injected latency must land in the time columns
    // (stall while gathering, comms while shipping), nowhere else.
    let wire_s = |log: &dad::coordinator::TrainLog| -> f64 {
        log.epochs.iter().map(|e| e.timing.stall_s + e.timing.comms_s).sum()
    };
    for (e, s) in slow.epochs.iter().enumerate() {
        assert!(
            s.timing.stall_s + s.timing.comms_s > 0.0,
            "epoch {e}: delayed run recorded no wire time at all: {:?}",
            s.timing
        );
    }
    let (clean_wire, slow_wire) = (wire_s(&clean), wire_s(&slow));
    assert!(
        slow_wire > clean_wire && slow_wire > 2e-3,
        "injected delay must show up in stall_s/comms_s: clean {clean_wire:.6}s, \
         slow {slow_wire:.6}s"
    );
}

/// The residual-carrying sparse family makes the same determinism
/// guarantee under faults: losing a site mid-run discards only that
/// site's error-feedback state (residual + DGC momentum are site-local),
/// so two same-seed `dgc-mid-drop` runs degrade identically — same loss
/// trajectory, same sparse-frame byte counts, same survivor schedule.
#[test]
fn same_seed_sparse_fault_runs_are_identical() {
    let recipe = find_recipe("dgc-mid-drop").unwrap();
    assert_eq!(recipe.expect, Expectation::Degrade(2), "precondition: degrade, not refuse");
    let a = run_recipe(&recipe, false).log.expect("run a");
    let b = run_recipe(&recipe, false).log.expect("run b");
    assert_eq!(a.epochs.len(), b.epochs.len());
    for (e, (x, y)) in a.epochs.iter().zip(&b.epochs).enumerate() {
        assert_eq!(x.train_loss, y.train_loss, "epoch {e}: loss not reproducible");
        assert_eq!(x.bytes_up, y.bytes_up, "epoch {e}: uplink bytes not reproducible");
        assert_eq!(x.bytes_down, y.bytes_down, "epoch {e}: downlink bytes not reproducible");
        assert_eq!(x.sites_live, y.sites_live, "epoch {e}: survivor schedule not reproducible");
    }
    assert_eq!(a.epochs.last().unwrap().sites_live, 2, "run must end degraded to 2 survivors");
}

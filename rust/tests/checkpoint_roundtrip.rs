//! Checkpoint/resume acceptance, loopback side: a run interrupted at
//! epoch k and resumed must be **bit-identical** to a run that was never
//! interrupted — same per-epoch losses (to the bit), same ledger bytes,
//! and the checkpoint file each writes at the end must match byte for
//! byte. Also the robustness contract: truncated, corrupted and
//! version-skewed files are rejected with clean named errors, never
//! panics.

use std::path::{Path, PathBuf};

use dad::algos::AlgoSpec;
use dad::checkpoint::{Checkpoint, CheckpointPlan, CkptMeta, CKPT_VERSION};
use dad::coordinator::{build_task, train_checkpointed, Scale, Schedule, TrainLog, TrainSpec, TrainTask};
use dad::dist::wire::WIRE_VERSION;
use dad::tensor::{Matrix, Rng};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dad-ckpt-tests-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(name)
}

fn plan_at(path: &Path, dataset: &str) -> CheckpointPlan {
    CheckpointPlan {
        save_path: Some(path.to_string_lossy().into_owned()),
        every: 0,
        dataset: dataset.to_string(),
        scale: "quick".to_string(),
    }
}

fn spec_for(algo: AlgoSpec, epochs: usize) -> TrainSpec {
    TrainSpec {
        algo,
        n_sites: 2,
        batch_per_site: 8,
        epochs,
        lr: 1e-3,
        seed: 23,
        schedule: Schedule::EveryBatch,
    }
}

/// One checkpointed loopback run on the quick-scale task for `dataset`.
fn run(
    dataset: &str,
    spec: &TrainSpec,
    plan: &CheckpointPlan,
    resume: Option<Checkpoint>,
) -> std::io::Result<TrainLog> {
    match build_task(dataset, Scale::Quick, spec.n_sites, spec.seed).expect("task") {
        TrainTask::Dense { train_ds, test_ds, shards, model } => {
            train_checkpointed(model, spec, &train_ds, &shards, &test_ds, plan, resume)
        }
        TrainTask::Seq { train_ds, test_ds, shards, model } => {
            train_checkpointed(model, spec, &train_ds, &shards, &test_ds, plan, resume)
        }
        TrainTask::Tokens { train_ds, test_ds, shards, model } => {
            train_checkpointed(model, spec, &train_ds, &shards, &test_ds, plan, resume)
        }
    }
}

/// The acceptance criterion: interrupt at epoch 2, resume to 4, compare
/// against an uninterrupted 4-epoch run — logs bit-equal on the resumed
/// tail, final checkpoint files byte-equal.
fn resume_matches_uninterrupted(algo: AlgoSpec, dataset: &str, tag: &str) {
    let name = algo.name();
    let (a, b, c) =
        (tmp(&format!("{tag}-a.ckpt")), tmp(&format!("{tag}-b.ckpt")), tmp(&format!("{tag}-c.ckpt")));
    run(dataset, &spec_for(algo.clone(), 2), &plan_at(&a, dataset), None).expect("interrupted run");
    // Atomic save: the temp file must not survive a successful rename.
    assert!(!a.with_extension("ckpt.tmp").exists(), "{name}: stale save temp file");
    let ck = Checkpoint::load(&a).expect("load interrupted checkpoint");
    assert_eq!(ck.meta.next_epoch, 2, "{name}: wrong resume cursor");
    assert_eq!(ck.meta.algo, name, "{name}: wrong algo in meta");

    let log_b =
        run(dataset, &spec_for(algo.clone(), 4), &plan_at(&b, dataset), Some(ck)).expect("resumed run");
    let log_c =
        run(dataset, &spec_for(algo, 4), &plan_at(&c, dataset), None).expect("uninterrupted run");

    assert_eq!(log_b.epochs.len(), 2, "{name}: resumed run must execute epochs 3..4 only");
    assert_eq!(log_c.epochs.len(), 4);
    for (rb, rc) in log_b.epochs.iter().zip(&log_c.epochs[2..]) {
        assert_eq!(rb.epoch, rc.epoch, "{name}: epoch numbering diverged");
        assert_eq!(
            rb.train_loss.to_bits(),
            rc.train_loss.to_bits(),
            "{name} epoch {}: resumed loss {} vs uninterrupted {}",
            rb.epoch,
            rb.train_loss,
            rc.train_loss
        );
        assert_eq!(rb.test_auc.to_bits(), rc.test_auc.to_bits(), "{name}: AUC diverged");
        assert_eq!(rb.test_acc.to_bits(), rc.test_acc.to_bits(), "{name}: accuracy diverged");
        assert_eq!(rb.bytes_up, rc.bytes_up, "{name}: uplink bytes diverged");
        assert_eq!(rb.bytes_down, rc.bytes_down, "{name}: downlink bytes diverged");
    }
    let bytes_b = std::fs::read(&b).expect("read resumed checkpoint");
    let bytes_c = std::fs::read(&c).expect("read uninterrupted checkpoint");
    assert_eq!(
        bytes_b, bytes_c,
        "{name}: resumed and uninterrupted runs wrote different checkpoint files"
    );
}

#[test]
fn resume_is_bit_identical_for_dad_on_mnist() {
    resume_matches_uninterrupted(AlgoSpec::Dad, "mnist", "dad-mnist");
}

/// DGC keeps per-site momentum/velocity/residual tables across steps —
/// the `ckpt-algo` frame must carry them or the resumed trajectory
/// diverges from the uninterrupted one.
#[test]
fn resume_is_bit_identical_for_dgc_on_mnist() {
    resume_matches_uninterrupted(AlgoSpec::Dgc { density: 25.0 }, "mnist", "dgc-mnist");
}

/// PowerSGD warm-starts its Q factors and accumulates error feedback —
/// cross-step state the checkpoint must restore exactly.
#[test]
fn resume_is_bit_identical_for_powersgd_on_mnist() {
    resume_matches_uninterrupted(AlgoSpec::PowerSgd { rank: 4 }, "mnist", "psgd-mnist");
}

#[test]
fn resume_is_bit_identical_for_dad_on_lm() {
    resume_matches_uninterrupted(AlgoSpec::Dad, "lm", "dad-lm");
}

#[test]
fn checkpointing_requires_every_batch_schedule() {
    let spec = TrainSpec { schedule: Schedule::Periodic(2), ..spec_for(AlgoSpec::Dad, 2) };
    let path = tmp("periodic.ckpt");
    let err = run("mnist", &spec, &plan_at(&path, "mnist"), None)
        .expect_err("periodic + checkpoint must be rejected");
    assert!(err.to_string().contains("sync-every"), "unclear error: {err}");
}

#[test]
fn resume_refuses_changed_run_identity() {
    let path = tmp("identity.ckpt");
    run("mnist", &spec_for(AlgoSpec::Dad, 2), &plan_at(&path, "mnist"), None).expect("seed run");
    let load = || Checkpoint::load(&path).expect("load");
    let none = CheckpointPlan::default();

    let lr_changed = TrainSpec { lr: 5e-4, ..spec_for(AlgoSpec::Dad, 4) };
    let err = run("mnist", &lr_changed, &none, Some(load())).expect_err("lr change must be refused");
    assert!(err.to_string().contains("lr"), "error does not name the field: {err}");

    let algo_changed = spec_for(AlgoSpec::Dsgd, 4);
    let err = run("mnist", &algo_changed, &none, Some(load())).expect_err("algo change");
    assert!(err.to_string().contains("algo"), "error does not name the field: {err}");

    // Same epoch count the checkpoint already completed: nothing to do.
    let err = run("mnist", &spec_for(AlgoSpec::Dad, 2), &none, Some(load()))
        .expect_err("completed checkpoint must not resume");
    assert!(err.to_string().contains("nothing to resume"), "unclear error: {err}");
}

// ---------------------------------------------------------------------------
// Robustness: malformed files are rejected cleanly
// ---------------------------------------------------------------------------

fn small_checkpoint() -> Checkpoint {
    let mut rng = Rng::new(7);
    let shapes = [(4, 3), (1, 3)];
    let mk = |rng: &mut Rng| {
        shapes.iter().map(|&(r, c)| Matrix::randn(r, c, 1.0, rng)).collect::<Vec<_>>()
    };
    Checkpoint {
        meta: CkptMeta {
            algo: "dad".into(),
            dataset: "mnist".into(),
            scale: "quick".into(),
            n_sites: 2,
            batch_per_site: 8,
            epochs: 4,
            lr: 1e-3,
            seed: 23,
            sync_every: 1,
            next_epoch: 2,
            adam_t: 50,
            rng_state: 0x0123_4567_89AB_CDEF,
            rng_inc: 0xFEDC_BA98_7654_3211,
            rng_spare: None,
        },
        params: mk(&mut rng),
        adam_m: mk(&mut rng),
        adam_v: mk(&mut rng),
        algo_state: vec![],
    }
}

/// Proptest-style exhaustive sweeps: every possible truncation and every
/// single-byte corruption of a valid container must decode to a clean
/// `Err` — the checksum (or an earlier structural check) catches all of
/// them, and nothing panics.
#[test]
fn every_truncation_and_byte_flip_is_rejected() {
    let bytes = small_checkpoint().encode();
    assert!(Checkpoint::decode_bytes(&bytes).is_ok(), "baseline image must decode");
    for k in 0..bytes.len() {
        assert!(
            Checkpoint::decode_bytes(&bytes[..k]).is_err(),
            "truncation to {k} of {} bytes decoded successfully",
            bytes.len()
        );
    }
    for i in 0..bytes.len() {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0xFF;
        assert!(
            Checkpoint::decode_bytes(&corrupt).is_err(),
            "flipping byte {i} of {} went undetected",
            bytes.len()
        );
    }
    // Trailing garbage after a valid image is also rejected.
    let mut padded = bytes.clone();
    padded.push(0);
    let err = Checkpoint::decode_bytes(&padded).unwrap_err();
    assert!(err.to_string().contains("trailing"), "unclear error: {err}");
}

#[test]
fn rejection_errors_name_the_failure() {
    let bytes = small_checkpoint().encode();

    let mut bad_magic = bytes.clone();
    bad_magic[0] ^= 1;
    let err = Checkpoint::decode_bytes(&bad_magic).unwrap_err();
    assert!(err.to_string().contains("magic"), "unclear error: {err}");

    let mut bad_ckpt = bytes.clone();
    bad_ckpt[8] = CKPT_VERSION + 1;
    let err = Checkpoint::decode_bytes(&bad_ckpt).unwrap_err();
    assert!(err.to_string().contains("container version"), "unclear error: {err}");

    let mut bad_wire = bytes.clone();
    bad_wire[9] = WIRE_VERSION + 1;
    let err = Checkpoint::decode_bytes(&bad_wire).unwrap_err();
    assert!(err.to_string().contains("wire version"), "unclear error: {err}");

    // Flipping the stored checksum (the file's final bytes) trips the
    // integrity check by name.
    let mut bad_sum = bytes.clone();
    let last = bad_sum.len() - 1;
    bad_sum[last] ^= 0xFF;
    let err = Checkpoint::decode_bytes(&bad_sum).unwrap_err();
    assert!(err.to_string().contains("checksum"), "unclear error: {err}");

    // Load errors mention the path.
    let missing = tmp("does-not-exist.ckpt");
    let err = Checkpoint::load(&missing).unwrap_err();
    assert!(err.to_string().contains("does-not-exist"), "unclear error: {err}");
}

#[test]
fn plan_due_schedule() {
    let plan = CheckpointPlan {
        save_path: Some("x.ckpt".into()),
        every: 2,
        dataset: "mnist".into(),
        scale: "quick".into(),
    };
    assert!(!plan.due(1, 5));
    assert!(plan.due(2, 5));
    assert!(!plan.due(3, 5));
    assert!(plan.due(4, 5));
    assert!(plan.due(5, 5), "the final epoch always saves");
    let disabled = CheckpointPlan::default();
    assert!(!disabled.enabled());
    assert!(!disabled.due(5, 5));
}

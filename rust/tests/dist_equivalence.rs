//! Cross-module integration: full multi-epoch training runs must produce
//! identical trajectories for pooled/dSGD/dAD/edAD (the paper's Figures
//! 1-2 claim, asserted numerically rather than visually).

use dad::algos::AlgoSpec;
use dad::coordinator::{train, Schedule, TrainSpec};
use dad::data::{arabic_digits_like, mnist_like, split_by_label};
use dad::nn::{Activation, GruClassifier, Mlp};
use dad::tensor::Rng;

fn spec(algo: AlgoSpec, epochs: usize) -> TrainSpec {
    TrainSpec {
        algo,
        n_sites: 2,
        batch_per_site: 16,
        epochs,
        lr: 1e-3,
        seed: 5,
        schedule: Schedule::EveryBatch,
    }
}

#[test]
fn mlp_four_algorithms_same_trajectory() {
    let mut rng = Rng::new(41);
    let full = mnist_like(560, &mut rng);
    let train_ds = full.subset(&(0..440).collect::<Vec<_>>());
    let test_ds = full.subset(&(440..560).collect::<Vec<_>>());
    let shards = split_by_label(&train_ds.labels, 10, 2);
    let model = || {
        let mut r = Rng::new(9);
        Mlp::new(&[784, 64, 32, 10], &[Activation::Relu, Activation::Relu], &mut r)
    };
    let logs: Vec<_> = [AlgoSpec::Pooled, AlgoSpec::Dsgd, AlgoSpec::Dad, AlgoSpec::Edad]
        .into_iter()
        .map(|a| train(model(), &spec(a, 2), &train_ds, &shards, &test_ds))
        .collect();
    // All four loss trajectories agree to f32 noise — the training is
    // literally the same optimization.
    for e in 0..2 {
        let base = logs[0].epochs[e].train_loss;
        for log in &logs[1..] {
            let l = log.epochs[e].train_loss;
            assert!(
                (l - base).abs() < 5e-3 * (1.0 + base.abs()),
                "epoch {e}: {} vs pooled {}",
                l,
                base
            );
        }
        let base_auc = logs[0].epochs[e].test_auc;
        for log in &logs[1..] {
            assert!((log.epochs[e].test_auc - base_auc).abs() < 2e-2);
        }
    }
    // And learning actually happened.
    assert!(logs[0].final_auc() > 0.75, "pooled AUC {}", logs[0].final_auc());
}

#[test]
fn gru_dad_edad_trajectories_match() {
    let mut rng = Rng::new(43);
    let full = arabic_digits_like(200, &mut rng);
    let train_ds = full.subset(&(0..160).collect::<Vec<_>>());
    let test_ds = full.subset(&(160..200).collect::<Vec<_>>());
    let shards = split_by_label(&train_ds.labels, 10, 2);
    let model = || {
        let mut r = Rng::new(9);
        GruClassifier::new(13, 16, &[32], 10, &mut r)
    };
    let log_dad = train(model(), &spec(AlgoSpec::Dad, 2), &train_ds, &shards, &test_ds);
    let log_edad = train(model(), &spec(AlgoSpec::Edad, 2), &train_ds, &shards, &test_ds);
    for e in 0..2 {
        let (a, b) = (log_dad.epochs[e].train_loss, log_edad.epochs[e].train_loss);
        assert!((a - b).abs() < 5e-3 * (1.0 + a.abs()), "epoch {e}: dad {a} vs edad {b}");
    }
    // edAD strictly cheaper on the wire.
    assert!(log_edad.total_bytes() < log_dad.total_bytes());
}

#[test]
fn rankdad_higher_rank_is_no_worse() {
    let mut rng = Rng::new(47);
    let full = mnist_like(400, &mut rng);
    let train_ds = full.subset(&(0..320).collect::<Vec<_>>());
    let test_ds = full.subset(&(320..400).collect::<Vec<_>>());
    let shards = split_by_label(&train_ds.labels, 10, 2);
    let model = || {
        let mut r = Rng::new(9);
        Mlp::new(&[784, 64, 10], &[Activation::Relu], &mut r)
    };
    let lo = train(
        model(),
        &spec(AlgoSpec::RankDad { max_rank: 1, n_iters: 10, theta: 1e-3 }, 3),
        &train_ds,
        &shards,
        &test_ds,
    );
    let hi = train(
        model(),
        &spec(AlgoSpec::RankDad { max_rank: 8, n_iters: 10, theta: 1e-3 }, 3),
        &train_ds,
        &shards,
        &test_ds,
    );
    // Figure 3's qualitative shape: more rank, no (significant) loss.
    assert!(hi.final_auc() > lo.final_auc() - 0.05, "hi {} lo {}", hi.final_auc(), lo.final_auc());
    // And rank-1 ships fewer bytes.
    assert!(lo.total_bytes() < hi.total_bytes());
}

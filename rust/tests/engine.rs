//! Integration tests for the rebuilt compute engine: GEMM parity against
//! the naive oracle across pool widths (DAD_THREADS swept via pool
//! shutdown/reinit), bit-exact workspace-reuse determinism, and pool
//! lifecycle safety.

use std::sync::Mutex;

use dad::nn::loss::one_hot;
use dad::nn::model::{Batch, DistModel};
use dad::nn::stats::LocalStats;
use dad::nn::{Activation, Mlp};
use dad::tensor::{matmul, matmul_nt, matmul_tn, ops, pool, Matrix, Rng, Workspace};

/// The pool is process-global; tests that reconfigure it must not overlap
/// (cargo's test harness runs tests on multiple threads).
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn pool_lock() -> std::sync::MutexGuard<'static, ()> {
    // A failed assertion in one test must not mask the others behind
    // PoisonError; the guarded resource (the global pool) is reset by
    // with_threads' drop guard anyway.
    POOL_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Run `f` on a freshly initialized pool of `n` threads, then tear the
/// pool down and restore the environment — even if `f` panics.
fn with_threads(n: usize, f: impl FnOnce()) {
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            pool::shutdown();
            std::env::remove_var("DAD_THREADS");
        }
    }
    pool::shutdown();
    std::env::set_var("DAD_THREADS", n.to_string());
    let _restore = Restore;
    assert_eq!(pool::num_threads(), n, "pool must re-read DAD_THREADS on reinit");
    f();
}

fn close(a: &Matrix, b: &Matrix, tol: f32, what: &str) {
    assert_eq!(a.shape(), b.shape(), "{what}: shape");
    let d = a.max_abs_diff(b);
    assert!(d < tol, "{what}: max abs diff {d} >= {tol}");
}

#[test]
fn gemm_parity_across_thread_counts() {
    let _guard = pool_lock();
    for &nt in &[1usize, 4, 16] {
        with_threads(nt, || {
            let mut rng = Rng::new(7 + nt as u64);
            // Shapes straddling the parallel threshold, including the
            // paper's batch-64 hot shapes and awkward odd sizes.
            for &(m, k, n) in &[
                (1usize, 1usize, 1usize),
                (5, 3, 9),
                (17, 13, 29),
                (64, 784, 256),
                (64, 300, 301),
                (129, 65, 131),
            ] {
                let a = Matrix::randn(m, k, 1.0, &mut rng);
                let b = Matrix::randn(k, n, 1.0, &mut rng);
                let oracle = ops::matmul_naive(&a, &b);
                close(&matmul(&a, &b), &oracle, 1e-2, &format!("matmul {m}x{k}x{n} nt={nt}"));
                // C = Aᵀ B with A = (k, m): compare via explicit transpose.
                let at = a.transpose();
                close(
                    &matmul_tn(&at, &b),
                    &oracle,
                    1e-2,
                    &format!("matmul_tn {m}x{k}x{n} nt={nt}"),
                );
                // C = A Bᵀ with B = (n, k): compare via explicit transpose.
                let bt = b.transpose();
                close(
                    &matmul_nt(&a, &bt),
                    &oracle,
                    1e-2,
                    &format!("matmul_nt {m}x{k}x{n} nt={nt}"),
                );
            }
        });
    }
}

#[test]
fn workspace_reuse_is_bit_deterministic() {
    let _guard = pool_lock();
    let mut rng = Rng::new(11);
    let mlp = Mlp::new(&[40, 64, 32, 10], &[Activation::Relu, Activation::Tanh], &mut rng);
    let x = Matrix::randn(48, 40, 1.0, &mut rng);
    let labels: Vec<usize> = (0..48).map(|i| i % 10).collect();
    let batch = Batch::Dense { x, y: one_hot(&labels, 10) };

    // Reference: the allocating one-shot path.
    let fresh = mlp.local_stats(&batch);

    // Two identical calls on one reused workspace + output: stats must be
    // bit-identical to each other AND to the fresh path (per-row summation
    // order is fixed regardless of which pool lane computes a row).
    let mut ws = Workspace::new();
    let mut out = LocalStats::empty();
    mlp.local_stats_into(&batch, &mut ws, &mut out);
    let first: Vec<(Matrix, Matrix)> =
        out.entries.iter().map(|e| (e.a.clone(), e.d.clone())).collect();
    let first_loss = out.loss;
    mlp.local_stats_into(&batch, &mut ws, &mut out);
    assert_eq!(out.loss.to_bits(), first_loss.to_bits(), "loss must be bit-stable");
    assert_eq!(out.entries.len(), first.len());
    for (i, e) in out.entries.iter().enumerate() {
        assert_eq!(e.a, first[i].0, "entry {i} A stack drifted across reuse");
        assert_eq!(e.d, first[i].1, "entry {i} Δ stack drifted across reuse");
        assert_eq!(e.a, fresh.entries[i].a, "entry {i} A stack differs from fresh path");
        assert_eq!(e.d, fresh.entries[i].d, "entry {i} Δ stack differs from fresh path");
    }
    assert_eq!(out.loss.to_bits(), fresh.loss.to_bits());
}

#[test]
fn pool_shutdown_and_reinit_are_safe() {
    let _guard = pool_lock();
    let mut rng = Rng::new(3);
    let a = Matrix::randn(96, 200, 1.0, &mut rng);
    let b = Matrix::randn(200, 96, 1.0, &mut rng);
    let want = ops::matmul_naive(&a, &b);
    // Use, shut down, use again (auto-reinit), double-shutdown (no-op).
    close(&matmul(&a, &b), &want, 1e-2, "pre-shutdown");
    pool::shutdown();
    pool::shutdown(); // idempotent
    close(&matmul(&a, &b), &want, 1e-2, "post-reinit");
    // Width changes take effect across a shutdown boundary.
    with_threads(2, || {
        close(&matmul(&a, &b), &want, 1e-2, "nt=2");
    });
    with_threads(1, || {
        assert_eq!(dad::tensor::parallel::num_threads(), 1);
        close(&matmul(&a, &b), &want, 1e-2, "nt=1");
    });
}

#[test]
fn per_site_workspaces_match_across_algorithms() {
    let _guard = pool_lock();
    use dad::algos::common::DistAlgorithm;
    use dad::algos::{Dad, Pooled};
    use dad::dist::Cluster;
    let mut rng = Rng::new(21);
    let mlp = Mlp::new(&[12, 16, 4], &[Activation::Relu], &mut rng);
    let batches: Vec<Batch> = (0..2)
        .map(|_| {
            let x = Matrix::randn(6, 12, 1.0, &mut rng);
            let labels: Vec<usize> = (0..6).map(|i| i % 4).collect();
            Batch::Dense { x, y: one_hot(&labels, 4) }
        })
        .collect();
    // Multiple steps on the SAME cluster reuse the per-site workspaces;
    // gradients must stay equal to the pooled oracle on every step.
    let mut c_dad = Cluster::replicate(mlp.clone(), 2);
    let mut c_pool = Cluster::replicate(mlp, 2);
    for step in 0..3 {
        let g_dad = Dad.step(&mut c_dad, &batches).grads;
        let g_pool = Pooled.step(&mut c_pool, &batches).grads;
        for (i, (gd, gp)) in g_dad.iter().zip(&g_pool).enumerate() {
            let diff = gd.max_abs_diff(gp);
            assert!(diff < 1e-5, "step {step} param {i}: {diff}");
        }
    }
}

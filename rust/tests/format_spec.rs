//! The format spec (`docs/FORMATS.md`) is normative: these tests parse
//! the version constants and pricing claims out of the document and
//! assert they equal what the crate actually compiles, so the spec
//! cannot silently drift from the code.

use dad::checkpoint::{fnv1a64, CKPT_MAGIC, CKPT_VERSION};
use dad::dist::wire::{sparse_wire_len, SparseMat, MAX_FRAME_LEN, WIRE_VERSION};
use dad::obs::metrics::METRIC_NAMES;

const SPEC: &str = include_str!("../docs/FORMATS.md");

/// Extract the integer documented on a `NAME = value` line.
fn documented(name: &str) -> u64 {
    let line = SPEC
        .lines()
        .map(str::trim)
        .find(|l| l.starts_with(name))
        .unwrap_or_else(|| panic!("FORMATS.md documents no `{name} = ...` line"));
    let value = line
        .split('=')
        .nth(1)
        .unwrap_or_else(|| panic!("malformed spec line {line:?}"))
        .trim();
    value.parse().unwrap_or_else(|_| panic!("non-integer spec value in {line:?}"))
}

#[test]
fn documented_versions_match_compiled_constants() {
    assert_eq!(
        documented("WIRE_VERSION"),
        u64::from(WIRE_VERSION),
        "docs/FORMATS.md documents a different wire version than the codec compiles; \
         update the spec (frame table + version history) alongside the constant"
    );
    assert_eq!(
        documented("CKPT_VERSION"),
        u64::from(CKPT_VERSION),
        "docs/FORMATS.md documents a different checkpoint container version than the \
         crate compiles; update §3 alongside the constant"
    );
}

#[test]
fn version_history_covers_the_current_version() {
    // The §1.4 history table must have a row for the version we speak.
    let row = format!("| {WIRE_VERSION} |");
    assert!(
        SPEC.contains(&row),
        "FORMATS.md §1.4 version history has no row for wire version {WIRE_VERSION}"
    );
}

#[test]
fn documented_magic_and_frame_limit_match() {
    assert_eq!(&CKPT_MAGIC[..7], b"DADCKPT");
    assert_eq!(CKPT_MAGIC[7], 0);
    assert!(SPEC.contains("DADCKPT"), "FORMATS.md does not document the magic bytes");
    // §1 documents the 2^30 frame-length ceiling.
    assert_eq!(MAX_FRAME_LEN, 1 << 30);
    assert!(SPEC.contains("2^30"), "FORMATS.md does not document MAX_FRAME_LEN");
}

#[test]
fn documented_sparse_pricing_matches_codec() {
    // §1.2: 8 bytes per nonzero over a 12-byte per-matrix header.
    assert!(SPEC.contains("8 bytes"), "FORMATS.md does not state the per-nonzero price");
    let m = SparseMat { rows: 4, cols: 5, idx: vec![0, 3, 17], vals: vec![1.0, -2.0, 0.5] };
    assert_eq!(m.wire_bytes(), 12 + 8 * 3);
    // Whole-frame size: 4 len + 1 version + 1 kind + 1 tag len + tag
    // + u16 count + per-matrix body, exactly as the §1 table lays out.
    let tag = "sparse-grad";
    assert_eq!(sparse_wire_len(tag, &[&m]), 4 + 3 + tag.len() as u64 + 2 + m.wire_bytes());
}

#[test]
fn documented_checksum_parameters_match() {
    // §3 names the FNV-1a 64 offset basis and prime; hashing nothing
    // returns the basis, and one NUL byte exercises the prime.
    assert!(SPEC.contains("0xcbf29ce484222325"), "spec lost the FNV offset basis");
    assert!(SPEC.contains("0x100000001b3"), "spec lost the FNV prime");
    assert_eq!(fnv1a64(&[]), 0xcbf2_9ce4_8422_2325);
    assert_eq!(fnv1a64(&[0]), 0xcbf2_9ce4_8422_2325_u64.wrapping_mul(0x0100_0000_01b3));
}

#[test]
fn spec_documents_every_live_tag() {
    // §2: a frame tag used by the protocols must appear in the spec's tag
    // vocabulary. Spot-check the full set, including the serving and
    // checkpoint families added with wire version 5.
    for tag in [
        "acts", "deltas", "aux-acts", "delta-L", "grad", "lowrank-q", "lowrank-g", "psgd-p",
        "psgd-q", "sparse-grad", "bias-grad", "direct-grad", "hello", "welcome", "config",
        "step-meta", "step-sync", "eff-rank", "local-loss", "epoch-sync", "resume", "infer-hello",
        "infer-welcome", "infer-req", "infer-res", "infer-shutdown", "ckpt-meta", "ckpt-params",
        "ckpt-adam-m", "ckpt-adam-v", "ckpt-algo", "ckpt-end",
    ] {
        assert!(SPEC.contains(&format!("`{tag}`")), "FORMATS.md tag table is missing `{tag}`");
    }
}

#[test]
fn spec_documents_every_exposed_metric() {
    // §6: each name `/metrics` serves must appear (backticked) in the
    // inventory, so renaming a metric forces a spec update.
    for name in METRIC_NAMES {
        assert!(
            SPEC.contains(&format!("`{name}`")),
            "FORMATS.md §6 metric inventory is missing `{name}`"
        );
    }
}

#[test]
fn spec_documents_the_trace_record_schema() {
    // §6: the JSONL span-record keys and phase vocabulary are normative —
    // `dad trace summarize` and external tooling parse them.
    for key in ["name", "tag", "phase", "ts_ns", "dur_ns", "tid", "thread"] {
        assert!(
            SPEC.contains(&format!("\"{key}\"")),
            "FORMATS.md §6 trace schema is missing the \"{key}\" key"
        );
    }
    for phase in ["`compute`", "`comms`", "`stall`", "`compress`"] {
        assert!(SPEC.contains(phase), "FORMATS.md §6 phase vocabulary is missing {phase}");
    }
    assert!(SPEC.contains("`_meta`"), "FORMATS.md §6 does not document the `_meta` footer");
}

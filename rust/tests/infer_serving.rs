//! End-to-end inference serving: train one epoch, checkpoint, boot
//! `InferServer` on an ephemeral port, and drive it over real TCP —
//! answers must match a direct `predict` on the same parameters, for
//! serial clients, concurrent (batched) clients, the MLP and the LM.
//! Plus request validation, the load generator, clean shutdown, and
//! unservable-checkpoint rejection.

use std::path::PathBuf;
use std::thread;
use std::time::Duration;

use dad::algos::AlgoSpec;
use dad::checkpoint::{Checkpoint, CheckpointPlan, CkptMeta};
use dad::coordinator::{build_task, train_checkpointed, Scale, Schedule, TrainSpec, TrainTask};
use dad::infer::{run_bench, InferClient, InferOpts, InferServer};
use dad::nn::model::{Batch, DistModel};
use dad::nn::{Mlp, Transformer};
use dad::tensor::Matrix;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dad-infer-tests-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(name)
}

fn spec_1_epoch() -> TrainSpec {
    TrainSpec {
        algo: AlgoSpec::Dad,
        n_sites: 2,
        batch_per_site: 8,
        epochs: 1,
        lr: 1e-3,
        seed: 17,
        schedule: Schedule::EveryBatch,
    }
}

/// Train one quick epoch on `dataset`, checkpoint it, load it back.
fn train_ckpt(dataset: &str, file: &str) -> Checkpoint {
    let path = tmp(file);
    let spec = spec_1_epoch();
    let plan = CheckpointPlan {
        save_path: Some(path.to_string_lossy().into_owned()),
        every: 0,
        dataset: dataset.to_string(),
        scale: "quick".to_string(),
    };
    match build_task(dataset, Scale::Quick, spec.n_sites, spec.seed).expect("task") {
        TrainTask::Dense { train_ds, test_ds, shards, model } => {
            train_checkpointed(model, &spec, &train_ds, &shards, &test_ds, &plan, None)
        }
        TrainTask::Tokens { train_ds, test_ds, shards, model } => {
            train_checkpointed(model, &spec, &train_ds, &shards, &test_ds, &plan, None)
        }
        TrainTask::Seq { .. } => unreachable!("only mnist/lm checkpoints are served"),
    }
    .expect("training run");
    Checkpoint::load(&path).expect("load checkpoint")
}

/// Bind an ephemeral port and run the server on its own thread.
fn spawn_server(
    ck: Checkpoint,
    opts: InferOpts,
) -> (String, thread::JoinHandle<std::io::Result<u64>>) {
    let server = InferServer::bind("127.0.0.1:0", ck, opts).expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    (addr, thread::spawn(move || server.run()))
}

/// The checkpointed MLP, rebuilt exactly as the server rebuilds it.
fn mlp_from(ck: &Checkpoint) -> Mlp {
    match build_task("mnist", Scale::Quick, 2, ck.meta.seed).expect("task") {
        TrainTask::Dense { mut model, .. } => {
            model.set_params(&ck.params);
            model
        }
        _ => unreachable!(),
    }
}

/// What the server must answer for one dense row: argmax + its score.
fn expect_row(model: &Mlp, row: &[f32]) -> (usize, f32) {
    let c = *model.dims.last().expect("mlp has layers");
    let x = Matrix::from_vec(1, row.len(), row.to_vec());
    let scores = model.predict(&Batch::Dense { x, y: Matrix::zeros(1, c) });
    argmax_of(&scores, 0)
}

fn argmax_of(scores: &Matrix, row: usize) -> (usize, f32) {
    let cols = scores.cols();
    let data = &scores.data()[row * cols..(row + 1) * cols];
    let mut best = 0usize;
    for (i, &v) in data.iter().enumerate() {
        if v > data[best] {
            best = i;
        }
    }
    (best, data[best])
}

#[test]
fn mlp_serving_matches_direct_predict() {
    let ck = train_ckpt("mnist", "mlp.ckpt");
    let model = mlp_from(&ck);
    let (test_x, rows) = {
        match build_task("mnist", Scale::Quick, 2, ck.meta.seed).expect("task") {
            TrainTask::Dense { test_ds, .. } => {
                let n = test_ds.x.rows().min(8);
                (test_ds.x, n)
            }
            _ => unreachable!(),
        }
    };
    let (addr, handle) = spawn_server(ck, InferOpts::default());

    let mut client = InferClient::connect(&addr).expect("connect");
    let info = client.info().clone();
    assert_eq!(info.model, "mlp");
    assert_eq!(info.in_dim, 784);
    assert_eq!(info.out_dim, 10);
    assert_eq!(info.max_t, 0, "the MLP accepts no token windows");

    // Serial requests are batches of one: bit-identical to direct predict.
    let d = test_x.cols();
    for i in 0..rows {
        let row = &test_x.data()[i * d..(i + 1) * d];
        let (cls, score) = client.classify(row).expect("classify");
        let (want_cls, want_score) = expect_row(&model, row);
        assert_eq!(cls, want_cls, "row {i}: served class diverged");
        assert_eq!(
            score.to_bits(),
            want_score.to_bits(),
            "row {i}: served score {score} vs direct {want_score}"
        );
    }

    // A malformed request is rejected by name without dropping the
    // connection; the next valid request still answers.
    let err = client.classify(&[0.0; 5]).expect_err("wrong width must be rejected");
    assert!(err.to_string().contains("features"), "unclear error: {err}");
    let row = &test_x.data()[0..d];
    assert_eq!(client.classify(row).expect("post-rejection request").0, expect_row(&model, row).0);

    client.shutdown().expect("shutdown");
    let served = handle.join().expect("server thread").expect("server run");
    assert!(served >= rows as u64 + 1, "server under-counted: served {served}");
}

/// Concurrent clients land in shared batches (small window, small cap —
/// the batcher must split and regroup). Every response must still be the
/// right one for *that* request.
#[test]
fn concurrent_clients_get_their_own_answers() {
    let ck = train_ckpt("mnist", "mlp-conc.ckpt");
    let model = mlp_from(&ck);
    let test_x = match build_task("mnist", Scale::Quick, 2, ck.meta.seed).expect("task") {
        TrainTask::Dense { test_ds, .. } => test_ds.x,
        _ => unreachable!(),
    };
    let opts = InferOpts { max_batch: 4, window: Duration::from_millis(1) };
    let (addr, handle) = spawn_server(ck, opts);

    let d = test_x.cols();
    let n_threads = 6usize;
    let per_thread = 5usize;
    let workers: Vec<_> = (0..n_threads)
        .map(|w| {
            let addr = addr.clone();
            // Each worker gets its own row set, staggered across the pool.
            let rows: Vec<(Vec<f32>, usize)> = (0..per_thread)
                .map(|k| {
                    let i = (w * per_thread + k) % test_x.rows();
                    let row = test_x.data()[i * d..(i + 1) * d].to_vec();
                    let want = expect_row(&model, &row).0;
                    (row, want)
                })
                .collect();
            thread::spawn(move || {
                let mut client = InferClient::connect(&addr).expect("connect");
                for (row, want) in rows {
                    let (cls, _score) = client.classify(&row).expect("classify");
                    assert_eq!(cls, want, "batched answer routed to the wrong request");
                }
            })
        })
        .collect();
    for w in workers {
        w.join().expect("worker");
    }

    InferClient::connect(&addr).expect("connect").shutdown().expect("shutdown");
    let served = handle.join().expect("server thread").expect("server run");
    assert_eq!(served, (n_threads * per_thread) as u64);
}

#[test]
fn lm_serving_matches_direct_predict() {
    let ck = train_ckpt("lm", "lm.ckpt");
    let tf: Transformer = match build_task("lm", Scale::Quick, 2, ck.meta.seed).expect("task") {
        TrainTask::Tokens { mut model, .. } => {
            model.set_params(&ck.params);
            model
        }
        _ => unreachable!(),
    };
    let (addr, handle) = spawn_server(ck, InferOpts::default());

    let mut client = InferClient::connect(&addr).expect("connect");
    let info = client.info().clone();
    assert_eq!(info.model, "lm");
    assert_eq!(info.in_dim, 0, "the LM accepts no dense rows");
    assert_eq!(info.out_dim, tf.cfg.vocab);
    assert_eq!(info.max_t, tf.cfg.max_t);

    for t in 1..=info.max_t {
        let ids: Vec<u32> = (0..t).map(|k| (k % info.out_dim) as u32).collect();
        let (tok, score) = client.next_token(&ids).expect("next_token");
        let scores = tf.predict(&Batch::Tokens {
            b: 1,
            t,
            ids: ids.clone(),
            targets: vec![0; t],
        });
        let (want_tok, want_score) = argmax_of(&scores, t - 1);
        assert_eq!(tok, want_tok, "t={t}: served next token diverged");
        assert_eq!(score.to_bits(), want_score.to_bits(), "t={t}: served score diverged");
    }

    // Validation: out-of-vocabulary id and over-long window, by name.
    let err = client.next_token(&[9999]).expect_err("oov id must be rejected");
    assert!(err.to_string().contains("vocabulary"), "unclear error: {err}");
    let long: Vec<u32> = vec![0; info.max_t + 1];
    let err = client.next_token(&long).expect_err("over-long window must be rejected");
    assert!(err.to_string().contains("window"), "unclear error: {err}");

    client.shutdown().expect("shutdown");
    handle.join().expect("server thread").expect("server run");
}

#[test]
fn bench_reports_sane_numbers() {
    let ck = train_ckpt("mnist", "mlp-bench.ckpt");
    let (addr, handle) = spawn_server(ck, InferOpts::default());

    let report = run_bench(&addr, 16, 2, 5).expect("bench");
    assert_eq!(report.requests, 16);
    assert_eq!(report.concurrency, 2);
    assert!(report.qps > 0.0, "qps must be positive, got {}", report.qps);
    assert!(
        report.p50_ms <= report.p99_ms,
        "p50 {} above p99 {}",
        report.p50_ms,
        report.p99_ms
    );
    let json = report.to_json();
    for key in ["\"model\"", "\"requests\"", "\"p50_ms\"", "\"p99_ms\"", "\"qps\"", "\"wall_s\""] {
        assert!(json.contains(key), "BENCH_serving.json is missing {key}: {json}");
    }

    InferClient::connect(&addr).expect("connect").shutdown().expect("shutdown");
    let served = handle.join().expect("server thread").expect("server run");
    assert_eq!(served, 16, "bench issued 16 ok requests");
}

#[test]
fn unservable_checkpoints_are_rejected_by_name() {
    // The arabic GRU has no request encoding: rejected before any socket.
    let meta = CkptMeta {
        algo: "dad".into(),
        dataset: "arabic".into(),
        scale: "quick".into(),
        n_sites: 2,
        batch_per_site: 8,
        epochs: 1,
        lr: 1e-3,
        seed: 17,
        sync_every: 1,
        next_epoch: 1,
        adam_t: 10,
        rng_state: 1,
        rng_inc: 3,
        rng_spare: None,
    };
    let gru_ck = Checkpoint {
        meta,
        params: vec![],
        adam_m: vec![],
        adam_v: vec![],
        algo_state: vec![],
    };
    let err = InferServer::bind("127.0.0.1:0", gru_ck, InferOpts::default())
        .expect_err("arabic checkpoint must be rejected");
    assert!(err.to_string().contains("not servable"), "unclear error: {err}");

    // A checkpoint whose parameters do not fit the model its meta
    // describes is rejected before serving garbage.
    let mut bad = train_ckpt("mnist", "mlp-bad.ckpt");
    bad.params.pop();
    let err = InferServer::bind("127.0.0.1:0", bad, InferOpts::default())
        .expect_err("shape-mismatched checkpoint must be rejected");
    assert!(err.to_string().contains("fit"), "unclear error: {err}");
}

#![cfg(feature = "pjrt")]

//! Integration tests across the AOT boundary: the Rust PJRT runtime
//! executing the JAX/Pallas-lowered artifacts must agree with the native
//! engine. Requires `make artifacts` to have been run (the Makefile test
//! target guarantees the ordering).

use dad::nn::loss::one_hot;
use dad::nn::model::{Batch, DistModel};
use dad::nn::Mlp;
use dad::runtime::{MlpBackend, NativeMlpBackend, PjrtMlpBackend};
use dad::runtime::pjrt::{PjrtInput, PjrtRuntime};
use dad::tensor::{Matrix, Rng};

fn artifacts_ready() -> bool {
    PjrtRuntime::default_dir().join("smoke.hlo.txt").is_file()
}

#[test]
fn smoke_artifact_runs() {
    if !artifacts_ready() {
        panic!("artifacts missing: run `make artifacts` first");
    }
    let mut rt = PjrtRuntime::cpu(PjrtRuntime::default_dir()).unwrap();
    // smoke: fn(x, y) = (matmul(x, y) + 2.0,) over f32[2,2].
    let x = PjrtInput { dims: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] };
    let y = PjrtInput { dims: vec![2, 2], data: vec![1.0, 1.0, 1.0, 1.0] };
    let out = rt.execute("smoke", &[x, y]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].data, vec![5.0, 5.0, 9.0, 9.0]);
    assert_eq!(rt.platform().to_lowercase(), "cpu");
}

#[test]
fn pjrt_mlp_stats_match_native() {
    if !artifacts_ready() {
        panic!("artifacts missing: run `make artifacts` first");
    }
    let mut rng = Rng::new(3);
    let mlp = Mlp::paper_mnist(&mut rng);
    let x = Matrix::rand_uniform(32, 784, 0.0, 1.0, &mut rng);
    let labels: Vec<usize> = (0..32).map(|i| i % 10).collect();
    let batch = Batch::Dense { x, y: one_hot(&labels, 10) };

    let native = NativeMlpBackend.local_stats(&mlp, &batch).unwrap();
    let mut pjrt = PjrtMlpBackend::from_default_artifacts().unwrap();
    let compiled = pjrt.local_stats(&mlp, &batch).unwrap();

    assert!(
        (native.loss - compiled.loss).abs() < 1e-4,
        "loss: native {} vs pjrt {}",
        native.loss,
        compiled.loss
    );
    assert_eq!(native.entries.len(), compiled.entries.len());
    for (i, (n, c)) in native.entries.iter().zip(&compiled.entries).enumerate() {
        assert_eq!(n.a.shape(), c.a.shape(), "entry {i} A shape");
        assert_eq!(n.d.shape(), c.d.shape(), "entry {i} D shape");
        let ea = n.a.max_abs_diff(&c.a);
        let ed = n.d.max_abs_diff(&c.d);
        assert!(ea < 1e-3, "entry {i} A diff {ea}");
        assert!(ed < 1e-3, "entry {i} D diff {ed}");
    }
}

#[test]
fn pjrt_grads_artifact_matches_native_outer_product() {
    if !artifacts_ready() {
        panic!("artifacts missing: run `make artifacts` first");
    }
    let mut rng = Rng::new(5);
    // mlp_grads artifact: concatenated stats at SN = 64.
    let a0 = Matrix::randn(64, 784, 1.0, &mut rng);
    let a1 = Matrix::randn(64, 1024, 1.0, &mut rng);
    let a2 = Matrix::randn(64, 1024, 1.0, &mut rng);
    let d1 = Matrix::randn(64, 1024, 1.0, &mut rng);
    let d2 = Matrix::randn(64, 1024, 1.0, &mut rng);
    let d3 = Matrix::randn(64, 10, 1.0, &mut rng);
    let scale = 1.0f32 / 64.0;
    let mut rt = PjrtRuntime::cpu(PjrtRuntime::default_dir()).unwrap();
    let out = rt
        .execute(
            "mlp_grads",
            &[
                PjrtInput::from_matrix(&a0),
                PjrtInput::from_matrix(&a1),
                PjrtInput::from_matrix(&a2),
                PjrtInput::from_matrix(&d1),
                PjrtInput::from_matrix(&d2),
                PjrtInput::from_matrix(&d3),
                PjrtInput::scalar(scale),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 6);
    // gw1 = scale * a0ᵀ d1 — compare against the native kernel.
    let gw1 = out[0].to_matrix();
    let want = dad::tensor::matmul_tn(&a0, &d1).scale(scale);
    let diff = gw1.max_abs_diff(&want);
    // 64-deep f32 reductions in different orders: allow 1e-3 absolute.
    assert!(diff < 5e-3, "gw1 diff {diff}");
    // gb3 = scale * colsum(d3).
    let gb3 = out[5].to_matrix();
    let want_b = Matrix::from_vec(1, 10, d3.col_sums()).scale(scale);
    assert!(gb3.max_abs_diff(&want_b) < 1e-4);
}

#[test]
fn pjrt_rankdad_factors_artifact_reconstructs() {
    if !artifacts_ready() {
        panic!("artifacts missing: run `make artifacts` first");
    }
    let mut rng = Rng::new(7);
    // Artifact traced at (64, 1024) x (64, 1024), max_rank 10, 10 iters.
    let a = Matrix::randn(64, 1024, 1.0, &mut rng);
    let d = Matrix::randn(64, 1024, 1.0, &mut rng);
    let mut rt = PjrtRuntime::cpu(PjrtRuntime::default_dir()).unwrap();
    let out = rt
        .execute(
            "rankdad_factors",
            &[PjrtInput::from_matrix(&a), PjrtInput::from_matrix(&d)],
        )
        .unwrap();
    assert_eq!(out.len(), 3);
    let q_t = out[0].to_matrix();
    let g_t = out[1].to_matrix();
    let eff = out[2].scalar() as usize;
    assert_eq!(q_t.shape(), (10, 1024));
    assert_eq!(g_t.shape(), (10, 1024));
    assert!(eff >= 1 && eff <= 10, "eff {eff}");
    // The rank-10 reconstruction must capture the top of the spectrum:
    // relative error strictly below 1 and sigma_0 within 5% of the native
    // engine's estimate.
    let m = dad::tensor::matmul_tn(&a, &d);
    let approx = dad::tensor::matmul_tn(&q_t, &g_t);
    let rel = approx.sub(&m).fro_norm() / m.fro_norm();
    assert!(rel < 1.0, "rel {rel}");
    let native = dad::lowrank::rankdad_factors(&a, &d, 10, 10, 1e-3);
    let sig0_pjrt: f32 = q_t.row(0).iter().map(|v| v * v).sum::<f32>().sqrt();
    let sig0_native: f32 = native.q_t.row(0).iter().map(|v| v * v).sum::<f32>().sqrt();
    let rel_sig = (sig0_pjrt - sig0_native).abs() / sig0_native;
    assert!(rel_sig < 0.05, "sigma0: pjrt {sig0_pjrt} vs native {sig0_native}");
}

/// End-to-end over the AOT boundary: one dAD exchange where every site's
/// stats come from the compiled artifact, gradients assembled natively,
/// compared against the all-native pipeline.
#[test]
fn dad_step_with_pjrt_stats_matches_native() {
    if !artifacts_ready() {
        panic!("artifacts missing: run `make artifacts` first");
    }
    let mut rng = Rng::new(11);
    let mlp = Mlp::paper_mnist(&mut rng);
    let mk_batch = |rng: &mut Rng| {
        let x = Matrix::rand_uniform(32, 784, 0.0, 1.0, rng);
        let labels: Vec<usize> = (0..32).map(|i| i % 10).collect();
        Batch::Dense { x, y: one_hot(&labels, 10) }
    };
    let b1 = mk_batch(&mut rng);
    let b2 = mk_batch(&mut rng);
    let mut pjrt = PjrtMlpBackend::from_default_artifacts().unwrap();
    let s1 = pjrt.local_stats(&mlp, &b1).unwrap();
    let s2 = pjrt.local_stats(&mlp, &b2).unwrap();
    // Aggregate (the dAD exchange) and assemble.
    let refs: Vec<&[dad::nn::StatsEntry]> = vec![&s1.entries, &s2.entries];
    let cat = dad::nn::stats::concat_stats(&refs);
    let shapes = mlp.param_shapes();
    let grads_pjrt = dad::nn::stats::assemble_grads(&shapes, &cat, &[], 1.0 / 64.0, 1.0);
    // Native oracle.
    let n1 = mlp.local_stats(&b1);
    let n2 = mlp.local_stats(&b2);
    let refs_n: Vec<&[dad::nn::StatsEntry]> = vec![&n1.entries, &n2.entries];
    let cat_n = dad::nn::stats::concat_stats(&refs_n);
    let grads_native = dad::nn::stats::assemble_grads(&shapes, &cat_n, &[], 1.0 / 64.0, 1.0);
    for (i, (p, n)) in grads_pjrt.iter().zip(&grads_native).enumerate() {
        let diff = p.max_abs_diff(n);
        assert!(diff < 1e-3, "param {i} grad diff {diff}");
    }
}

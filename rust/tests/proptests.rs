//! Property-based tests over the coordinator invariants, driven by the
//! in-repo `testing` substrate (no proptest crate offline). Each property
//! runs across a seeded family of random shapes/values and shrinks nothing
//! — failures print the seed for exact reproduction.

use dad::algos::common::DistAlgorithm;
use dad::algos::{Dad, Dsgd, Edad, Pooled, RankDad, RankDadConfig, SparseAlgo};
use dad::dist::wire::{self, Body, SparseMat};
use dad::dist::Cluster;
use dad::nn::loss::one_hot;
use dad::nn::model::{Batch, DistModel};
use dad::nn::{Activation, Mlp};
use dad::tensor::{matmul_tn, Matrix, Rng};

/// Deterministic case fan-out helper.
fn forall(cases: usize, seed: u64, mut prop: impl FnMut(u64, &mut Rng)) {
    for case in 0..cases {
        let case_seed = seed.wrapping_mul(1_000_003).wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        prop(case_seed, &mut rng);
    }
}

fn random_mlp(rng: &mut Rng) -> Mlp {
    let depth = 1 + rng.below(3);
    let mut dims = vec![3 + rng.below(20)];
    for _ in 0..depth {
        dims.push(2 + rng.below(24));
    }
    dims.push(2 + rng.below(6)); // classes
    let acts: Vec<Activation> = (0..dims.len() - 2)
        .map(|_| match rng.below(3) {
            0 => Activation::Relu,
            1 => Activation::Tanh,
            _ => Activation::Sigmoid,
        })
        .collect();
    Mlp::new(&dims, &acts, rng)
}

fn random_batches(mlp: &Mlp, sites: usize, rng: &mut Rng) -> Vec<Batch> {
    let classes = *mlp.dims.last().unwrap();
    (0..sites)
        .map(|_| {
            let n = 2 + rng.below(10);
            let x = Matrix::randn(n, mlp.dims[0], 1.0, rng);
            let labels: Vec<usize> = (0..n).map(|_| rng.below(classes)).collect();
            Batch::Dense { x, y: one_hot(&labels, classes) }
        })
        .collect()
}

/// dAD == dSGD == edAD == pooled for random architectures, activations,
/// site counts and (unequal!) batch sizes.
#[test]
fn prop_exact_algorithms_agree() {
    forall(25, 0xA11CE, |seed, rng| {
        let mlp = random_mlp(rng);
        let sites = 2 + rng.below(3);
        let batches = random_batches(&mlp, sites, rng);
        let grads = |algo: &mut dyn DistAlgorithm<Mlp>| {
            let mut cluster = Cluster::replicate(mlp.clone(), sites);
            algo.step(&mut cluster, &batches).grads
        };
        let g_pooled = grads(&mut Pooled);
        let g_dsgd = grads(&mut Dsgd);
        let g_dad = grads(&mut Dad);
        let g_edad = grads(&mut Edad);
        for (i, p) in g_pooled.iter().enumerate() {
            let tol = 1e-4 * (1.0 + p.max_abs());
            assert!(p.max_abs_diff(&g_dsgd[i]) < tol, "seed {seed:#x} dsgd param {i}");
            assert!(p.max_abs_diff(&g_dad[i]) < tol, "seed {seed:#x} dad param {i}");
            assert!(p.max_abs_diff(&g_edad[i]) < tol, "seed {seed:#x} edad param {i}");
        }
    });
}

/// The gradient's rank never exceeds the global batch size: rank-dAD with
/// max_rank >= N must therefore be (near-)exact for any shape.
#[test]
fn prop_rankdad_exact_at_full_rank() {
    forall(12, 0xBEEF, |seed, rng| {
        let mlp = random_mlp(rng);
        let sites = 2;
        let batches = random_batches(&mlp, sites, rng);
        let mut cluster = Cluster::replicate(mlp.clone(), sites);
        let g_pooled = Pooled.step(&mut cluster, &batches).grads;
        let mut cluster2 = Cluster::replicate(mlp.clone(), sites);
        let mut algo =
            RankDad { cfg: RankDadConfig { max_rank: 16, n_iters: 60, theta: 1e-6 } };
        let g_rd = algo.step(&mut cluster2, &batches).grads;
        for (i, p) in g_pooled.iter().enumerate() {
            let tol = 5e-2 * (1.0 + p.max_abs());
            assert!(
                p.max_abs_diff(&g_rd[i]) < tol,
                "seed {seed:#x} param {i}: {} vs tol {tol}",
                p.max_abs_diff(&g_rd[i])
            );
        }
    });
}

/// Factor reconstruction error is monotonically non-increasing in rank.
#[test]
fn prop_factor_error_monotone_in_rank() {
    forall(15, 0xFACE, |seed, rng| {
        let n = 3 + rng.below(12);
        let h1 = 8 + rng.below(48);
        let h2 = 8 + rng.below(48);
        let a = Matrix::randn(n, h1, 1.0, rng);
        let d = Matrix::randn(n, h2, 1.0, rng);
        let m = matmul_tn(&a, &d);
        let mut last = f32::MAX;
        for r in [1usize, 2, 4, 8] {
            let f = dad::lowrank::rankdad_factors(&a, &d, r, 40, 1e-5);
            let err = f.reconstruct(1.0).sub(&m).fro_norm();
            assert!(
                err <= last * 1.01 + 1e-4,
                "seed {seed:#x} rank {r}: err {err} > last {last}"
            );
            last = err;
        }
    });
}

/// Ledger bytes are conserved: the sum over tag breakdown equals the total.
#[test]
fn prop_ledger_breakdown_consistent() {
    forall(10, 0xCAFE, |_seed, rng| {
        let mlp = random_mlp(rng);
        let batches = random_batches(&mlp, 2, rng);
        let mut cluster = Cluster::replicate(mlp.clone(), 2);
        let _ = Dad.step(&mut cluster, &batches);
        let total = cluster.ledger.total();
        let sum: u64 = cluster.ledger.breakdown().iter().map(|&(_, _, b)| b).sum();
        assert_eq!(total, sum);
        assert!(total > 0);
    });
}

/// Wire-codec round trip: payload frames with arbitrary shapes (including
/// empty matrices and multi-matrix direct-grad frames) decode to the exact
/// bits that were encoded, and the encoder's byte count always equals the
/// arithmetic `payload_wire_len` the loopback backend charges the ledger.
#[test]
fn prop_wire_payload_roundtrip() {
    forall(40, 0xF7A3E, |seed, rng| {
        let tags = ["acts", "deltas", "direct-grad", "grad", "lowrank-q"];
        let tag = tags[rng.below(tags.len())];
        let n_mats = 1 + rng.below(4);
        let mats: Vec<Matrix> = (0..n_mats)
            .map(|_| {
                // Empty shapes (0 rows or 0 cols) must survive too.
                let r = rng.below(12);
                let c = rng.below(40);
                Matrix::randn(r, c, 1.0, rng)
            })
            .collect();
        let refs: Vec<&Matrix> = mats.iter().collect();
        let mut buf = Vec::new();
        let written = wire::encode_payload(&mut buf, tag, &refs).unwrap();
        assert_eq!(written as usize, buf.len(), "seed {seed:#x}: length bookkeeping");
        assert_eq!(written, wire::payload_wire_len(tag, &refs), "seed {seed:#x}: arithmetic len");
        let frame = wire::decode(&mut buf.as_slice()).unwrap();
        assert_eq!(frame.tag, tag, "seed {seed:#x}");
        assert_eq!(frame.wire_len(), written, "seed {seed:#x}");
        match frame.body {
            Body::Mats(got) => {
                assert_eq!(got.len(), mats.len(), "seed {seed:#x}");
                for (g, m) in got.iter().zip(&mats) {
                    assert_eq!(g.shape(), m.shape(), "seed {seed:#x}");
                    assert_eq!(g, m, "seed {seed:#x}: bit-exact f32 round trip");
                }
            }
            other => panic!("seed {seed:#x}: payload decoded as {other:?}"),
        }
    });
}

/// Control frames round-trip random byte bodies, and back-to-back frames in
/// one stream decode in order (the property TCP links rely on).
#[test]
fn prop_wire_control_roundtrip_and_streaming() {
    forall(25, 0x5EED5, |seed, rng| {
        let n_frames = 1 + rng.below(5);
        let mut stream = Vec::new();
        let mut want: Vec<(String, Vec<u8>)> = Vec::new();
        for i in 0..n_frames {
            let tag = format!("ctl{i}");
            let body: Vec<u8> = (0..rng.below(64)).map(|_| rng.below(256) as u8).collect();
            wire::encode_control(&mut stream, &tag, &body).unwrap();
            want.push((tag, body));
        }
        let mut rd = stream.as_slice();
        for (tag, body) in &want {
            let f = wire::decode(&mut rd).unwrap();
            assert_eq!(&f.tag, tag, "seed {seed:#x}");
            match f.body {
                Body::Control(b) => assert_eq!(&b, body, "seed {seed:#x}"),
                other => panic!("seed {seed:#x}: control decoded as {other:?}"),
            }
        }
        assert!(rd.is_empty(), "seed {seed:#x}: stream fully consumed");
    });
}

/// The ledger's serialized-byte accounting exceeds the raw f32 payload by
/// exactly the framing overhead: per-frame header + 8 bytes per matrix.
#[test]
fn prop_ledger_counts_framing_overhead() {
    forall(10, 0xBEADED, |seed, rng| {
        let mlp = random_mlp(rng);
        let batches = random_batches(&mlp, 2, rng);
        let mut cluster = Cluster::replicate(mlp.clone(), 2);
        let _ = Dad.step(&mut cluster, &batches);
        let measured = cluster.ledger.total();
        // Reconstruct the raw f32 bytes dAD ships (up: per-site stacks;
        // down: the concatenated stacks) and the exact frame count.
        let stats: Vec<_> = batches.iter().map(|b| mlp.local_stats(b)).collect();
        let mut raw = 0u64;
        let mut frames = 0u64;
        for s in &stats {
            for e in &s.entries {
                raw += e.a.wire_bytes() + e.d.wire_bytes();
                frames += 2;
            }
        }
        // Broadcast of the vertcat doubles the raw stat bytes, one frame
        // per concatenated stack.
        raw *= 2;
        frames += 2 * stats[0].entries.len() as u64;
        let per_mat = 8; // rows + cols dims
        let per_frame_hdr = |tag: &str| 4 + 3 + tag.len() as u64 + 2;
        // Every dad frame tag is "acts" or "deltas"; count them exactly.
        let n_acts = stats[0].entries.len() as u64 * 3; // 2 uplinks + 1 broadcast
        let n_deltas = n_acts;
        let overhead = n_acts * (per_frame_hdr("acts") + per_mat)
            + n_deltas * (per_frame_hdr("deltas") + per_mat);
        assert_eq!(frames, n_acts + n_deltas, "seed {seed:#x}: frame census");
        assert_eq!(measured, raw + overhead, "seed {seed:#x}: measured = raw + framing");
    });
}

/// Sparse wire-codec round trip: random shapes and transmit sets —
/// including empty, singleton and dense-limit index sets — decode to the
/// exact bits encoded, and the encoder's byte count always equals the
/// arithmetic `sparse_wire_len` the loopback backend charges the ledger.
#[test]
fn prop_wire_sparse_roundtrip() {
    forall(40, 0x5BA23E, |seed, rng| {
        let tags = ["sparse-grad", "sg", "top-k"];
        let tag = tags[rng.below(tags.len())];
        let n_mats = 1 + rng.below(3);
        let mats: Vec<SparseMat> = (0..n_mats)
            .map(|_| {
                let r = rng.below(12);
                let c = rng.below(40);
                let numel = r * c;
                let m = Matrix::randn(r, c, 1.0, rng);
                let keep: Vec<u32> = match rng.below(4) {
                    0 => vec![],                                     // empty
                    1 if numel > 0 => vec![rng.below(numel) as u32], // singleton
                    2 => (0..numel as u32).collect(),                // dense limit
                    _ => (0..numel as u32).filter(|_| rng.below(3) == 0).collect(),
                };
                SparseMat::from_dense(&m, &keep)
            })
            .collect();
        let refs: Vec<&SparseMat> = mats.iter().collect();
        let mut buf = Vec::new();
        let written = wire::encode_sparse(&mut buf, tag, &refs).unwrap();
        assert_eq!(written as usize, buf.len(), "seed {seed:#x}: length bookkeeping");
        assert_eq!(written, wire::sparse_wire_len(tag, &refs), "seed {seed:#x}: arithmetic len");
        // Framing overhead, reconstructed independently: frame header +
        // per-matrix dims/nnz header + 8 bytes (u32 idx + f32 val) per
        // transmitted element — the index overhead must be on the wire.
        let arith = (4 + 3 + tag.len() as u64 + 2)
            + mats.iter().map(|m| 12 + 8 * m.nnz() as u64).sum::<u64>();
        assert_eq!(written, arith, "seed {seed:#x}: index overhead accounting");
        let frame = wire::decode(&mut buf.as_slice()).unwrap();
        assert_eq!(frame.tag, tag, "seed {seed:#x}");
        assert_eq!(frame.wire_len(), written, "seed {seed:#x}");
        match frame.body {
            Body::Sparse(got) => {
                assert_eq!(got, mats, "seed {seed:#x}: bit-exact sparse round trip")
            }
            other => panic!("seed {seed:#x}: sparse decoded as {other:?}"),
        }
    });
}

/// Corrupt sparse frames are rejected as clean protocol errors, never
/// panics: an out-of-range index and a non-increasing (duplicate) index
/// each fail decode with `InvalidData` for arbitrary shapes.
#[test]
fn prop_wire_sparse_rejects_bad_indices() {
    forall(30, 0xBAD5EED, |seed, rng| {
        let r = 1 + rng.below(8);
        let c = 2 + rng.below(16);
        let numel = (r * c) as u32;
        let m = Matrix::randn(r, c, 1.0, rng);
        let keep: Vec<u32> = (0..numel).collect();
        let sm = SparseMat::from_dense(&m, &keep);
        let tag = "sparse-grad";
        let mut good = Vec::new();
        wire::encode_sparse(&mut good, tag, &[&sm]).unwrap();
        // Byte layout: prefix(4) ver/kind/taglen(3) tag n_mats(2)
        // rows/cols/nnz(12), then the index array.
        let base = 4 + 3 + tag.len() + 2 + 12;

        // (a) Out of range: overwrite the last index with numel.
        let mut bad = good.clone();
        let off = base + (sm.nnz() - 1) * 4;
        bad[off..off + 4].copy_from_slice(&numel.to_le_bytes());
        let err = wire::decode(&mut bad.as_slice()).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "seed {seed:#x}: {err}");
        assert!(err.to_string().contains("out of range"), "seed {seed:#x}: {err}");

        // (b) Duplicate: make the second index equal the first.
        let mut bad = good.clone();
        bad[base + 4..base + 8].copy_from_slice(&sm.idx[0].to_le_bytes());
        let err = wire::decode(&mut bad.as_slice()).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "seed {seed:#x}: {err}");
        assert!(err.to_string().contains("strictly increasing"), "seed {seed:#x}: {err}");
    });
}

/// The ledger's sparse accounting includes the u32 index overhead: a
/// full-density VBC step (λ=0 transmits every element) charges, per
/// entry, exactly two uplinked and one broadcast `sparse-grad` frame at
/// 8 bytes per element plus headers — alongside the dense dSGD-style
/// bias frames — for arbitrary architectures.
#[test]
fn prop_sparse_ledger_counts_index_overhead() {
    forall(10, 0x1DE7EC7, |seed, rng| {
        let mlp = random_mlp(rng);
        let batches = random_batches(&mlp, 2, rng);
        let mut cluster = Cluster::replicate(mlp.clone(), 2);
        let mut algo = SparseAlgo::vbc(0.0);
        let _ = algo.step(&mut cluster, &batches);
        let measured = cluster.ledger.total();
        let stats = mlp.local_stats(&batches[0]);
        let shapes = mlp.param_shapes();
        let hdr = |tag: &str| 4 + 3 + tag.len() as u64 + 2;
        let mut expect = 0u64;
        for e in &stats.entries {
            let (wr, wc) = shapes[e.w_idx];
            // 2 uplinks + 1 broadcast; λ=0 keeps every element, so each
            // frame ships numel (index, value) pairs after a 12-byte
            // dims/nnz header.
            expect += 3 * (hdr("sparse-grad") + 12 + 8 * (wr * wc) as u64);
            if let Some(bi) = e.b_idx {
                let (br, bc) = shapes[bi];
                expect += 3 * (hdr("bias-grad") + 8 + (br * bc * 4) as u64);
            }
        }
        assert_eq!(measured, expect, "seed {seed:#x}: sparse ledger census");
    });
}

/// `trainer::epoch_plan` is a permutation-free partition, and it is
/// bit-identical across independently-seeded processes — the property the
/// multi-process mode's "no index traffic on the wire" rests on. For every
/// shard: exactly `n / batch` full batches, all indices in range, no index
/// repeated within the epoch (the ragged tail is dropped, never recycled).
#[test]
fn prop_epoch_plan_is_deterministic_partition() {
    forall(40, 0x9_1A27, |seed, rng| {
        let n_sites = 1 + rng.below(4);
        let batch = 1 + rng.below(8);
        let sizes: Vec<usize> = (0..n_sites).map(|_| rng.below(40)).collect();
        let draw = |s: u64| {
            let mut r = Rng::new(s);
            dad::coordinator::epoch_plan(&sizes, batch, &mut r)
                .into_iter()
                .map(|it| it.collect::<Vec<Vec<usize>>>())
                .collect::<Vec<_>>()
        };
        let plan = draw(seed);
        // Two independently-seeded "processes" agree on every batch.
        assert_eq!(plan, draw(seed), "seed {seed:#x}: cross-process determinism");
        for (shard, batches) in plan.iter().enumerate() {
            let n = sizes[shard];
            assert_eq!(batches.len(), n / batch, "seed {seed:#x} shard {shard}: batch count");
            let mut seen = vec![false; n];
            for b in batches {
                assert_eq!(b.len(), batch, "seed {seed:#x} shard {shard}: full batches only");
                for &i in b {
                    assert!(i < n, "seed {seed:#x} shard {shard}: index {i} out of range");
                    assert!(!seen[i], "seed {seed:#x} shard {shard}: index {i} repeated");
                    seen[i] = true;
                }
            }
            // Partition, not just disjointness: exactly (n/batch)*batch
            // distinct indices are covered.
            let covered = seen.iter().filter(|&&s| s).count();
            assert_eq!(covered, (n / batch) * batch, "seed {seed:#x} shard {shard}: coverage");
        }
    });
}

/// Per-site stats wire size never exceeds dSGD's gradient wire size by the
/// paper's bound when N < min(h_i): the premise of the whole method.
#[test]
fn prop_stats_cheaper_than_grads_when_batch_small() {
    forall(15, 0xD00D, |seed, rng| {
        // Wide layers, small batch: the paper's regime.
        let h = 48 + rng.below(64);
        let mut r2 = rng.fork(1);
        let mlp = Mlp::new(&[h, h, 4 + rng.below(6)], &[Activation::Relu], &mut r2);
        let n = 2 + rng.below(8); // n << h
        let classes = *mlp.dims.last().unwrap();
        let x = Matrix::randn(n, h, 1.0, rng);
        let labels: Vec<usize> = (0..n).map(|_| rng.below(classes)).collect();
        let b = Batch::Dense { x, y: one_hot(&labels, classes) };
        let stats = mlp.local_stats(&b);
        let stat_bytes: u64 = stats.entries.iter().map(|e| e.wire_bytes()).sum();
        let grad_bytes: u64 = mlp
            .param_shapes()
            .iter()
            .map(|&(r, c)| (r * c * 4) as u64)
            .sum();
        assert!(
            stat_bytes < grad_bytes,
            "seed {seed:#x}: stats {stat_bytes} >= grads {grad_bytes} (h={h}, n={n})"
        );
    });
}

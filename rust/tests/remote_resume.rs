//! Checkpoint/resume acceptance, TCP side: a `dad serve` checkpoint is
//! byte-identical to the loopback `dad train` checkpoint of the same
//! trajectory, and a serve/join run resumed from it is bit-identical to
//! an uninterrupted serve/join run — closing the loop with the loopback
//! guarantees in `tests/checkpoint_roundtrip.rs`. Plus the remote-mode
//! restrictions (stateless algorithms, `--sync-every 1`) as named
//! errors.

use std::path::{Path, PathBuf};
use std::thread;

use dad::algos::AlgoSpec;
use dad::checkpoint::{Checkpoint, CheckpointPlan};
use dad::coordinator::{
    build_task, join_training_resumable, serve_training_checkpointed, train_checkpointed,
    FaultPolicy, ResumeMode, Scale, Schedule, TrainLog, TrainSpec, TrainTask,
};
use dad::data::DenseDataset;
use dad::dist::{Ledger, Loopback, TcpAgg, TcpSite};
use dad::nn::Mlp;

type MnistTask = (DenseDataset, DenseDataset, Vec<Vec<usize>>, Mlp);

fn mnist_task(seed: u64) -> MnistTask {
    match build_task("mnist", Scale::Quick, 2, seed).expect("task") {
        TrainTask::Dense { train_ds, test_ds, shards, model } => (train_ds, test_ds, shards, model),
        _ => unreachable!("mnist builds a dense task"),
    }
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dad-remote-resume-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(name)
}

fn plan_at(path: &Path) -> CheckpointPlan {
    CheckpointPlan {
        save_path: Some(path.to_string_lossy().into_owned()),
        every: 0,
        dataset: "mnist".to_string(),
        scale: "quick".to_string(),
    }
}

fn spec_for(epochs: usize) -> TrainSpec {
    TrainSpec {
        algo: AlgoSpec::Dad,
        n_sites: 2,
        batch_per_site: 8,
        epochs,
        lr: 1e-3,
        seed: 31,
        schedule: Schedule::EveryBatch,
    }
}

/// One checkpointed serve + 2-join run over real TCP sockets.
fn tcp_run(spec: &TrainSpec, plan: &CheckpointPlan, resume: Option<Checkpoint>) -> TrainLog {
    let listener = TcpAgg::bind("127.0.0.1:0", 2).expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let resume_mode = if resume.is_some() { ResumeMode::Checkpoint } else { ResumeMode::Fresh };
    let joins: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let spec = spec.clone();
            thread::spawn(move || {
                let mut t = TcpSite::connect(&addr).expect("connect");
                let site_id = t.site_id();
                let (train_ds, _test_ds, shards, model) = mnist_task(spec.seed);
                let mut ledger = Ledger::new();
                join_training_resumable(
                    &mut t, &mut ledger, &spec, model, &train_ds, &shards, site_id, resume_mode,
                )
                .expect("join")
            })
        })
        .collect();
    let mut agg = listener.accept_sites().expect("accept");
    let mut ledger = Ledger::new();
    let (train_ds, test_ds, shards, model) = mnist_task(spec.seed);
    let log = serve_training_checkpointed(
        &mut agg,
        &mut ledger,
        spec,
        model,
        &train_ds,
        &shards,
        &test_ds,
        FaultPolicy::default(),
        plan,
        resume,
        None,
    )
    .expect("serve");
    for j in joins {
        j.join().expect("join thread");
    }
    log
}

/// Loopback run of the same spec through the simulated trainer.
fn loopback_run(spec: &TrainSpec, plan: &CheckpointPlan, resume: Option<Checkpoint>) -> TrainLog {
    let (train_ds, test_ds, shards, model) = mnist_task(spec.seed);
    train_checkpointed(model, spec, &train_ds, &shards, &test_ds, plan, resume).expect("loopback")
}

/// The full acceptance chain in one scenario: serve checkpoints equal
/// loopback checkpoints byte-for-byte; a TCP run resumed from one is
/// bit-identical to the uninterrupted TCP run; and the resumed TCP run
/// lands on the same final state as the uninterrupted loopback run.
#[test]
fn tcp_resume_is_bit_identical_and_matches_loopback() {
    let (a_loop, a_tcp) = (tmp("a-loop.ckpt"), tmp("a-tcp.ckpt"));
    let (b_tcp, c_loop, d_tcp) = (tmp("b-tcp.ckpt"), tmp("c-loop.ckpt"), tmp("d-tcp.ckpt"));

    // Interrupted prefix (2 epochs), both modes.
    loopback_run(&spec_for(2), &plan_at(&a_loop), None);
    tcp_run(&spec_for(2), &plan_at(&a_tcp), None);
    assert_eq!(
        std::fs::read(&a_loop).expect("read loopback ckpt"),
        std::fs::read(&a_tcp).expect("read serve ckpt"),
        "a `dad serve` checkpoint must be byte-identical to the loopback checkpoint \
         of the same trajectory"
    );

    // Uninterrupted 4-epoch references, both modes.
    let log_c = loopback_run(&spec_for(4), &plan_at(&c_loop), None);
    let log_d = tcp_run(&spec_for(4), &plan_at(&d_tcp), None);
    assert_eq!(
        std::fs::read(&c_loop).expect("read"),
        std::fs::read(&d_tcp).expect("read"),
        "uninterrupted serve and loopback runs diverged"
    );

    // Resume the TCP checkpoint over TCP and finish to 4 epochs.
    let ck = Checkpoint::load(&a_tcp).expect("load");
    assert_eq!(ck.meta.next_epoch, 2);
    let log_b = tcp_run(&spec_for(4), &plan_at(&b_tcp), Some(ck));

    assert_eq!(log_b.epochs.len(), 2, "resumed run must execute epochs 3..4 only");
    for (rb, rd) in log_b.epochs.iter().zip(&log_d.epochs[2..]) {
        assert_eq!(rb.epoch, rd.epoch, "epoch numbering diverged");
        assert_eq!(
            rb.train_loss.to_bits(),
            rd.train_loss.to_bits(),
            "epoch {}: resumed TCP loss {} vs uninterrupted TCP {}",
            rb.epoch,
            rb.train_loss,
            rd.train_loss
        );
        assert_eq!(rb.test_auc.to_bits(), rd.test_auc.to_bits(), "AUC diverged");
        assert_eq!(rb.bytes_up, rd.bytes_up, "uplink bytes diverged");
        assert_eq!(rb.bytes_down, rd.bytes_down, "downlink bytes diverged");
    }
    // Cross-mode: the resumed TCP run lands on the loopback losses too.
    for (rb, rc) in log_b.epochs.iter().zip(&log_c.epochs[2..]) {
        assert_eq!(rb.train_loss.to_bits(), rc.train_loss.to_bits(), "TCP vs loopback loss");
    }
    assert_eq!(
        std::fs::read(&b_tcp).expect("read"),
        std::fs::read(&c_loop).expect("read"),
        "the checkpoint written by the resumed TCP run differs from the uninterrupted \
         loopback run's checkpoint"
    );
}

#[test]
fn remote_checkpoint_rejects_stateful_algorithms() {
    let spec = TrainSpec { algo: AlgoSpec::Dgc { density: 25.0 }, ..spec_for(2) };
    let path = tmp("dgc.ckpt");
    let (train_ds, test_ds, shards, model) = mnist_task(spec.seed);
    let mut t = Loopback::new(2);
    let mut ledger = Ledger::new();
    let err = serve_training_checkpointed(
        &mut t,
        &mut ledger,
        &spec,
        model,
        &train_ds,
        &shards,
        &test_ds,
        FaultPolicy::default(),
        &plan_at(&path),
        None,
        None,
    )
    .expect_err("dgc + remote checkpoint must be rejected");
    assert!(err.to_string().contains("compressor state"), "unclear error: {err}");

    // The join side guards resume with the same gate.
    let (train_ds, _test_ds, shards, model) = mnist_task(spec.seed);
    let err = join_training_resumable(
        &mut t, &mut ledger, &spec, model, &train_ds, &shards, 0, ResumeMode::Checkpoint,
    )
    .expect_err("dgc join resume must be rejected");
    assert!(err.to_string().contains("compressor state"), "unclear error: {err}");
}

#[test]
fn remote_checkpoint_rejects_periodic_schedules() {
    let spec = TrainSpec { schedule: Schedule::Periodic(2), ..spec_for(2) };
    let path = tmp("periodic.ckpt");
    let (train_ds, test_ds, shards, model) = mnist_task(spec.seed);
    let mut t = Loopback::new(2);
    let mut ledger = Ledger::new();
    let err = serve_training_checkpointed(
        &mut t,
        &mut ledger,
        &spec,
        model,
        &train_ds,
        &shards,
        &test_ds,
        FaultPolicy::default(),
        &plan_at(&path),
        None,
        None,
    )
    .expect_err("periodic + remote checkpoint must be rejected");
    assert!(err.to_string().contains("--sync-every 1"), "unclear error: {err}");
}

//! Transport equivalence end-to-end: the TCP multi-process mode must be
//! indistinguishable — in gradients, losses and ledger byte counts — from
//! the in-process loopback simulation with the same seed. The aggregator
//! and site "processes" run as threads here, but every frame crosses a real
//! localhost socket through the same code paths `dad serve` / `dad join`
//! use.

use std::thread;

use dad::algos::common::DistAlgorithm;
use dad::algos::{AlgoSpec, Dad};
use dad::coordinator::remote::{dad_agg_step, dad_site_step};
use dad::coordinator::{join_training, serve_training, train, Schedule, TrainSpec};
use dad::data::{mnist_like, split_by_label};
use dad::dist::{Cluster, Direction, Ledger, TcpAgg, TcpSite};
use dad::nn::loss::one_hot;
use dad::nn::model::{Batch, DistModel};
use dad::nn::{Activation, Mlp};
use dad::tensor::{Matrix, Rng, Workspace};

fn mk_model(seed: u64, dims: &[usize]) -> Mlp {
    let mut rng = Rng::new(seed);
    Mlp::new(dims, &vec![Activation::Relu; dims.len() - 2], &mut rng)
}

/// One dAD step over real TCP produces the same global gradient at every
/// endpoint and the same per-direction ledger bytes as the loopback
/// simulation — the tentpole acceptance check at step granularity.
#[test]
fn tcp_dad_step_matches_loopback_ledger_and_grads() {
    let mlp = mk_model(31, &[12, 18, 6]);
    let mut rng = Rng::new(77);
    let batches: Vec<Batch> = (0..2)
        .map(|_| {
            let x = Matrix::randn(5, 12, 1.0, &mut rng);
            let labels: Vec<usize> = (0..5).map(|i| i % 6).collect();
            Batch::Dense { x, y: one_hot(&labels, 6) }
        })
        .collect();

    // Loopback reference: one simulated dAD step.
    let mut cluster = Cluster::replicate(mlp.clone(), 2);
    let sim = Dad.step(&mut cluster, &batches);
    let sim_up = cluster.ledger.total_dir(Direction::SiteToAgg);
    let sim_down = cluster.ledger.total_dir(Direction::AggToSite);
    assert!(sim_up > 0 && sim_down > 0);

    // TCP run: an aggregator plus two sites, each with its own ledger.
    let listener = TcpAgg::bind("127.0.0.1:0", 2).expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let site_threads: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let model = mlp.clone();
            let batches = batches.clone();
            thread::spawn(move || {
                let mut t = TcpSite::connect(&addr).expect("connect");
                // The handshake assigns the id; pick this site's batch by it.
                let batch = batches[t.site_id()].clone();
                let mut ledger = Ledger::new();
                let mut ws = Workspace::new();
                let out = dad_site_step(&mut t, &mut ledger, &model, &batch, &mut ws)
                    .expect("site step");
                (out, ledger)
            })
        })
        .collect();
    let mut agg = listener.accept_sites().expect("accept");
    let mut agg_ledger = Ledger::new();
    let shapes = mlp.param_shapes();
    let agg_out = dad_agg_step(&mut agg, &mut agg_ledger, &shapes).expect("agg step");

    // The aggregator's ledger sees all traffic — it must equal the sim's.
    assert_eq!(agg_ledger.total_dir(Direction::SiteToAgg), sim_up, "uplink bytes");
    assert_eq!(agg_ledger.total_dir(Direction::AggToSite), sim_down, "downlink bytes");
    // Same tags, same per-tag totals.
    let mut sim_rows: Vec<_> = cluster.ledger.breakdown().to_vec();
    let mut tcp_rows: Vec<_> = agg_ledger.breakdown().to_vec();
    sim_rows.sort();
    tcp_rows.sort();
    assert_eq!(sim_rows, tcp_rows, "per-(tag, direction) ledger breakdown");

    // Every endpoint assembled the same exact global gradient.
    assert!((agg_out.loss - sim.loss).abs() < 1e-6, "loss");
    for (i, g) in sim.grads.iter().enumerate() {
        assert!(g.max_abs_diff(&agg_out.grads[i]) < 1e-6, "agg grad {i}");
    }
    let mut site_up_sum = 0;
    for h in site_threads {
        let (out, ledger) = h.join().expect("site thread");
        assert!((out.loss - sim.loss).abs() < 1e-6);
        for (i, g) in sim.grads.iter().enumerate() {
            assert!(g.max_abs_diff(&out.grads[i]) < 1e-6, "site grad {i}");
        }
        // A site's downlink view is the full broadcast...
        assert_eq!(ledger.total_dir(Direction::AggToSite), sim_down);
        site_up_sum += ledger.total_dir(Direction::SiteToAgg);
    }
    // ...and the sites' uplinks sum to the aggregator's uplink total.
    assert_eq!(site_up_sum, sim_up);
}

/// A full multi-epoch TCP training run (serve + 2 joins) reproduces the
/// simulated `train()` run: same loss trajectory, same per-epoch ledger
/// bytes — the ISSUE's acceptance criterion at training granularity.
#[test]
fn tcp_training_run_matches_simulated_run() {
    let spec = TrainSpec {
        algo: AlgoSpec::Dad,
        n_sites: 2,
        batch_per_site: 8,
        epochs: 2,
        lr: 1e-3,
        seed: 23,
        schedule: Schedule::EveryBatch,
    };
    // Simulated reference run (every "process" rebuilds the identical task
    // from the seed — see build_task_200 below).
    let (train_ds, test_ds, shards, model) = build_task_200(spec.seed);
    let sim_log = train(model, &spec, &train_ds, &shards, &test_ds);

    // TCP run: serve in this thread, two joins in workers.
    let listener = TcpAgg::bind("127.0.0.1:0", 2).expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let joins: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let spec = spec.clone();
            thread::spawn(move || {
                let mut t = TcpSite::connect(&addr).expect("connect");
                let site_id = t.site_id();
                let (train_ds, _test_ds, shards, model) = build_task_200(spec.seed);
                let mut ledger = Ledger::new();
                join_training(&mut t, &mut ledger, &spec, model, &train_ds, &shards, site_id)
                    .expect("join")
            })
        })
        .collect();
    let mut agg = listener.accept_sites().expect("accept");
    let mut ledger = Ledger::new();
    let (_train_ds, test_ds, shards, model) = build_task_200(spec.seed);
    let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
    let serve_log =
        serve_training(&mut agg, &mut ledger, &spec, model, &sizes, &test_ds).expect("serve");

    assert_eq!(serve_log.epochs.len(), sim_log.epochs.len());
    for (e, (srv, sim)) in serve_log.epochs.iter().zip(&sim_log.epochs).enumerate() {
        assert!(
            (srv.train_loss - sim.train_loss).abs() < 1e-6,
            "epoch {e}: tcp loss {} vs sim {}",
            srv.train_loss,
            sim.train_loss
        );
        assert_eq!(srv.bytes_up, sim.bytes_up, "epoch {e} uplink bytes");
        assert_eq!(srv.bytes_down, sim.bytes_down, "epoch {e} downlink bytes");
        assert!((srv.test_auc - sim.test_auc).abs() < 1e-5, "epoch {e} AUC");
    }
    for j in joins {
        let log = j.join().expect("join thread");
        // Sites see the same global per-step losses the aggregator logs.
        for (srv, site) in serve_log.epochs.iter().zip(&log.epochs) {
            assert!((srv.train_loss - site.train_loss).abs() < 1e-6);
        }
    }
}

/// Deterministic task construction shared by the sim run, the serve thread
/// and both join threads — same seed, bit-identical data/model everywhere.
fn build_task_200(
    seed: u64,
) -> (dad::data::DenseDataset, dad::data::DenseDataset, Vec<Vec<usize>>, Mlp) {
    let mut rng = Rng::new(seed);
    let full = mnist_like(200, &mut rng);
    let train_ds = full.subset(&(0..160).collect::<Vec<_>>());
    let test_ds = full.subset(&(160..200).collect::<Vec<_>>());
    let shards = split_by_label(&train_ds.labels, 10, 2);
    (train_ds, test_ds, shards, mk_model(9, &[784, 24, 10]))
}

//! Transport equivalence end-to-end: the TCP multi-process mode must be
//! indistinguishable — in gradients, losses and ledger byte counts — from
//! the in-process loopback simulation with the same seed, for **every**
//! algorithm in the family (`pooled | dsgd | dad | dad-p2p | edad |
//! rank-dad | powersgd | dgc | vbc | adacomp`), for periodic sync
//! schedules, and for every
//! batch layout — dense (MLP) *and* token (transformer LM) batches both
//! run through the same generic drivers. The aggregator and site
//! "processes" run as threads here, but every frame crosses a real
//! localhost socket through the same algorithm-agnostic protocol drivers
//! `dad serve` / `dad join` use.

use std::thread;
use std::time::Duration;

use dad::algos::common::DistAlgorithm;
use dad::algos::{concat_batches, AlgoSpec, StepOutcome};
use dad::checkpoint::CheckpointPlan;
use dad::coordinator::{
    build_task, join_training, join_training_resumable, relay_training, remote_agg_step,
    remote_site_step, serve_training, serve_training_checkpointed, train, validate_dataset_algo,
    validate_remote, validate_remote_topology, DataSource, FaultPolicy, RemoteConfig, RemoteStep,
    ResumeMode, Scale, Schedule, Topology, TrainLog, TrainSpec, TrainTask,
};
use dad::data::{mnist_like, split_by_label, Partition, TokenDataset};
use dad::dist::{
    ChaosSpec, ChaosTransport, Cluster, CostModel, Direction, Ledger, Loopback, TcpAgg, TcpSite,
    Transport,
};
use dad::nn::loss::one_hot;
use dad::nn::model::{Batch, DistModel};
use dad::nn::{Activation, Mlp, Transformer, TransformerConfig};
use dad::tensor::{Matrix, Rng, Workspace};

fn mk_model(seed: u64, dims: &[usize]) -> Mlp {
    let mut rng = Rng::new(seed);
    Mlp::new(dims, &vec![Activation::Relu; dims.len() - 2], &mut rng)
}

fn mk_batches(n_sites: usize, rows: usize, in_dim: usize, classes: usize, seed: u64) -> Vec<Batch> {
    let mut rng = Rng::new(seed);
    (0..n_sites)
        .map(|s| {
            let x = Matrix::randn(rows, in_dim, 1.0, &mut rng);
            // Disjoint-ish labels per site (the paper's non-IID flavor).
            let labels: Vec<usize> = (0..rows).map(|i| (s + i) % classes).collect();
            Batch::Dense { x, y: one_hot(&labels, classes) }
        })
        .collect()
}

/// Per-site token batches with (possibly uneven) window counts `bs[s]`.
fn mk_token_batches(bs: &[usize], t: usize, vocab: usize, seed: u64) -> Vec<Batch> {
    let mut rng = Rng::new(seed);
    bs.iter()
        .map(|&b| {
            let ids: Vec<u32> = (0..b * t).map(|_| rng.below(vocab) as u32).collect();
            let targets: Vec<u32> = (0..b * t).map(|_| rng.below(vocab) as u32).collect();
            Batch::Tokens { b, t, ids, targets }
        })
        .collect()
}

/// `steps` simulated synchronized steps on a loopback cluster; returns the
/// per-step outcomes and the cluster's final ledger.
fn sim_steps<M: DistModel + Clone>(
    spec: &AlgoSpec,
    model: &M,
    batches: &[Batch],
    steps: usize,
) -> (Vec<StepOutcome>, Ledger) {
    let mut cluster = Cluster::replicate(model.clone(), batches.len());
    let mut algo = spec.build::<M>();
    let outs: Vec<StepOutcome> = (0..steps).map(|_| algo.step(&mut cluster, batches)).collect();
    let ledger = cluster.ledger.clone();
    (outs, ledger)
}

/// The TCP counterpart: aggregator in this thread, one thread per site,
/// every endpoint driving `steps` remote steps through the generic
/// protocol drivers. Returns (aggregator outs, aggregator ledger,
/// per-site (outs, ledger)).
type SiteRun = (Vec<RemoteStep>, Ledger);

fn tcp_steps<M: DistModel + Clone + Send + 'static>(
    spec: &AlgoSpec,
    model: &M,
    batches: &[Batch],
    steps: usize,
) -> (Vec<RemoteStep>, Ledger, Vec<SiteRun>) {
    let n_sites = batches.len();
    let oracle = matches!(spec, AlgoSpec::Pooled);
    let listener = TcpAgg::bind("127.0.0.1:0", n_sites).expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let handles: Vec<_> = (0..n_sites)
        .map(|_| {
            let addr = addr.clone();
            let model = model.clone();
            let batches = batches.to_vec();
            let spec = spec.clone();
            thread::spawn(move || {
                let mut t = TcpSite::connect(&addr).expect("connect");
                let site_id = t.site_id();
                let mut proto = spec.build::<M>().protocol();
                let mut ledger = Ledger::new();
                let mut ws = Workspace::new();
                // The oracle trains the union batch in every process; the
                // handshake-assigned id picks the shard batch otherwise.
                let batch = if matches!(spec, AlgoSpec::Pooled) {
                    concat_batches(&batches)
                } else {
                    batches[site_id].clone()
                };
                let outs: Vec<RemoteStep> = (0..steps)
                    .map(|_| {
                        remote_site_step(
                            proto.as_mut(),
                            &mut t,
                            &mut ledger,
                            &model,
                            &batch,
                            site_id,
                            &mut ws,
                        )
                        .expect("site step")
                    })
                    .collect();
                (outs, ledger)
            })
        })
        .collect();
    let mut agg = listener.accept_sites().expect("accept");
    let mut ledger = Ledger::new();
    let mut proto = spec.build::<M>().protocol();
    let union_stats = oracle.then(|| model.local_stats(&concat_batches(batches)));
    let agg_outs: Vec<RemoteStep> = (0..steps)
        .map(|_| {
            remote_agg_step(
                proto.as_mut(),
                &mut agg,
                &mut ledger,
                model,
                union_stats.as_ref(),
                FaultPolicy::default(),
            )
            .expect("agg step")
        })
        .collect();
    let sites: Vec<SiteRun> = handles.into_iter().map(|h| h.join().expect("site thread")).collect();
    (agg_outs, ledger, sites)
}

fn sorted_rows(l: &Ledger) -> Vec<(String, Direction, u64)> {
    let mut rows = l.breakdown().to_vec();
    rows.sort();
    rows
}

/// Step-granularity equivalence for the whole algorithm family: same
/// grads, same losses, same per-(tag, direction) ledger bytes on real
/// sockets as in the loopback simulation — the tentpole acceptance check.
/// Two steps per algorithm so PowerSGD's cross-step error-feedback state
/// is exercised too.
#[test]
fn tcp_step_matches_loopback_for_every_algorithm() {
    let specs = [
        AlgoSpec::Pooled,
        AlgoSpec::Dsgd,
        AlgoSpec::Dad,
        AlgoSpec::DadP2p,
        AlgoSpec::Edad,
        AlgoSpec::RankDad { max_rank: 4, n_iters: 10, theta: 1e-3 },
        AlgoSpec::PowerSgd { rank: 4 },
        AlgoSpec::Dgc { density: 25.0 },
        AlgoSpec::Vbc { lambda: 2.0 },
        AlgoSpec::AdaComp { bin: 64 },
    ];
    let mlp = mk_model(31, &[12, 18, 6]);
    let batches = mk_batches(2, 5, 12, 6, 77);
    for spec in &specs {
        check_step_equivalence(spec, &mlp, &batches, 2);
    }
    // The all-to-all relay with more than two sites (3 receivers-1 paths).
    let batches3 = mk_batches(3, 4, 12, 6, 78);
    check_step_equivalence(&AlgoSpec::DadP2p, &mlp, &batches3, 2);
}

/// The same step-granularity equivalence on **token batches** through the
/// transformer LM, with *uneven* per-site window counts (2 vs 3 windows):
/// every supported algorithm must produce identical grads, losses and
/// per-(tag, direction) ledger bytes over real sockets as over loopback.
/// (edAD is excluded by design: the transformer rejects it up front —
/// covered by `remote_drivers_reject_edad_for_transformer`.)
#[test]
fn tcp_step_matches_loopback_for_token_batches() {
    let specs = [
        AlgoSpec::Pooled,
        AlgoSpec::Dsgd,
        AlgoSpec::Dad,
        AlgoSpec::DadP2p,
        AlgoSpec::RankDad { max_rank: 4, n_iters: 6, theta: 1e-3 },
        AlgoSpec::PowerSgd { rank: 4 },
        AlgoSpec::Dgc { density: 25.0 },
        AlgoSpec::Vbc { lambda: 2.0 },
        AlgoSpec::AdaComp { bin: 64 },
    ];
    let cfg = TransformerConfig::tiny();
    let mut rng = Rng::new(91);
    let model = Transformer::new(cfg.clone(), &mut rng);
    let batches = mk_token_batches(&[2, 3], 5, cfg.vocab, 92);
    for spec in &specs {
        check_step_equivalence(spec, &model, &batches, 2);
    }
}

fn check_step_equivalence<M: DistModel + Clone + Send + 'static>(
    spec: &AlgoSpec,
    model: &M,
    batches: &[Batch],
    steps: usize,
) {
    let name = spec.name();
    let (sim_outs, sim_ledger) = sim_steps(spec, model, batches, steps);
    let (agg_outs, agg_ledger, sites) = tcp_steps(spec, model, batches, steps);
    assert_eq!(agg_outs.len(), sim_outs.len());
    for (s, (sim, tcp)) in sim_outs.iter().zip(&agg_outs).enumerate() {
        assert!(
            (sim.loss - tcp.loss).abs() < 1e-6,
            "{name} step {s}: loss sim {} vs tcp {}",
            sim.loss,
            tcp.loss
        );
        for (i, g) in sim.grads.iter().enumerate() {
            let err = g.max_abs_diff(&tcp.grads[i]);
            assert!(err < 1e-6, "{name} step {s}: agg grad {i} err {err}");
        }
        assert_eq!(sim.eff_ranks, tcp.eff_ranks, "{name} step {s}: eff-rank telemetry");
        for (site, (outs, _)) in sites.iter().enumerate() {
            assert!((sim.loss - outs[s].loss).abs() < 1e-6, "{name} site {site} step {s} loss");
            for (i, g) in sim.grads.iter().enumerate() {
                let err = g.max_abs_diff(&outs[s].grads[i]);
                assert!(err < 1e-6, "{name} site {site} step {s}: grad {i} err {err}");
            }
        }
    }
    // The aggregator observes all traffic: its per-(tag, direction)
    // breakdown must equal the simulation's exactly.
    assert_eq!(sorted_rows(&sim_ledger), sorted_rows(&agg_ledger), "{name}: ledger breakdown");
    // Site-local views are consistent with the aggregate: uplinks (and
    // p2p shipments) sum to the aggregator's totals; every site saw the
    // full shared broadcast.
    let site_up: u64 = sites.iter().map(|(_, l)| l.total_dir(Direction::SiteToAgg)).sum();
    let site_p2p: u64 = sites.iter().map(|(_, l)| l.total_dir(Direction::PeerToPeer)).sum();
    assert_eq!(site_up, agg_ledger.total_dir(Direction::SiteToAgg), "{name}: uplink sum");
    assert_eq!(site_p2p, agg_ledger.total_dir(Direction::PeerToPeer), "{name}: p2p sum");
    for (site, (_, l)) in sites.iter().enumerate() {
        assert_eq!(
            l.total_dir(Direction::AggToSite),
            agg_ledger.total_dir(Direction::AggToSite),
            "{name}: site {site} downlink view"
        );
    }
}

/// Deterministic task construction shared by the sim run, the serve thread
/// and both join threads — same seed, bit-identical data/model everywhere.
fn build_task_200(
    seed: u64,
) -> (dad::data::DenseDataset, dad::data::DenseDataset, Vec<Vec<usize>>, Mlp) {
    let mut rng = Rng::new(seed);
    let full = mnist_like(200, &mut rng);
    let train_ds = full.subset(&(0..160).collect::<Vec<_>>());
    let test_ds = full.subset(&(160..200).collect::<Vec<_>>());
    let shards = split_by_label(&train_ds.labels, 10, 2);
    (train_ds, test_ds, shards, mk_model(9, &[784, 24, 10]))
}

/// A full multi-epoch TCP training run (serve + 2 joins) must reproduce
/// the simulated `train()` run: same loss trajectory, same per-epoch
/// ledger bytes, same evaluation — for the given spec and any task the
/// `build` closure constructs (dense MLP, token transformer, ...).
fn check_training_equivalence_with<M, D, F>(spec: &TrainSpec, build: F)
where
    M: DistModel + Clone + Send + 'static,
    D: DataSource,
    F: Fn() -> (D, D, Vec<Vec<usize>>, M) + Send + Clone + 'static,
{
    let (train_ds, test_ds, shards, model) = build();
    let sim_log = train(model, spec, &train_ds, &shards, &test_ds);

    let listener = TcpAgg::bind("127.0.0.1:0", 2).expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let joins: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let spec = spec.clone();
            let build = build.clone();
            thread::spawn(move || {
                let mut t = TcpSite::connect(&addr).expect("connect");
                let site_id = t.site_id();
                let (train_ds, _test_ds, shards, model) = build();
                let mut ledger = Ledger::new();
                join_training(&mut t, &mut ledger, &spec, model, &train_ds, &shards, site_id)
                    .expect("join")
            })
        })
        .collect();
    let mut agg = listener.accept_sites().expect("accept");
    let mut ledger = Ledger::new();
    let (train_ds, test_ds, shards, model) = build();
    let serve_log = serve_training(
        &mut agg,
        &mut ledger,
        spec,
        model,
        &train_ds,
        &shards,
        &test_ds,
        FaultPolicy::default(),
    )
    .expect("serve");

    let name = spec.algo.name();
    assert_eq!(serve_log.epochs.len(), sim_log.epochs.len());
    for (e, (srv, sim)) in serve_log.epochs.iter().zip(&sim_log.epochs).enumerate() {
        assert!(
            (srv.train_loss - sim.train_loss).abs() < 1e-6,
            "{name} epoch {e}: tcp loss {} vs sim {}",
            srv.train_loss,
            sim.train_loss
        );
        assert_eq!(srv.bytes_up, sim.bytes_up, "{name} epoch {e} uplink bytes");
        assert_eq!(srv.bytes_down, sim.bytes_down, "{name} epoch {e} downlink bytes");
        assert!((srv.test_auc - sim.test_auc).abs() < 1e-5, "{name} epoch {e} AUC");
        for (r_srv, r_sim) in srv.mean_eff_rank.iter().zip(&sim.mean_eff_rank) {
            assert!(
                (r_srv - r_sim).abs() < 1e-5 || (r_srv.is_nan() && r_sim.is_nan()),
                "{name} epoch {e}: eff-rank {r_srv} vs {r_sim}"
            );
        }
    }
    for j in joins {
        let log = j.join().expect("join thread");
        // Sites see the same global per-step losses the aggregator logs
        // (exact for every-batch schedules; local phases log site-local
        // losses on the sites, so periodic runs skip this check).
        if spec.schedule == Schedule::EveryBatch {
            for (srv, site) in serve_log.epochs.iter().zip(&log.epochs) {
                assert!((srv.train_loss - site.train_loss).abs() < 1e-6, "{name} site loss");
            }
        }
    }
}

/// [`check_training_equivalence_with`] on the standard 200-example dense
/// task.
fn check_training_equivalence(spec: &TrainSpec) {
    let seed = spec.seed;
    check_training_equivalence_with(spec, move || build_task_200(seed));
}

/// Deterministic LM task shared by the sim run, the serve thread and both
/// join threads — the exact construction `dad serve --dataset lm --scale
/// quick` and its joins perform.
fn build_lm_task(seed: u64) -> (TokenDataset, TokenDataset, Vec<Vec<usize>>, Transformer) {
    match build_task("lm", Scale::Quick, 2, seed).expect("lm task") {
        TrainTask::Tokens { train_ds, test_ds, shards, model } => {
            (train_ds, test_ds, shards, model)
        }
        _ => panic!("lm must build a token task"),
    }
}

/// The ISSUE's token acceptance criterion at training granularity: a full
/// multi-epoch `dad serve`/`dad join` run on the LM task reproduces the
/// simulated run — losses, per-epoch ledger bytes, and the token-aware
/// evaluation (AUC over the vocab, per-token accuracy, perplexity).
#[test]
fn tcp_lm_training_run_matches_simulated_run() {
    let spec = TrainSpec {
        algo: AlgoSpec::Dad,
        n_sites: 2,
        batch_per_site: 8,
        epochs: 2,
        lr: 1e-3,
        seed: 41,
        schedule: Schedule::EveryBatch,
    };
    check_training_equivalence_with(&spec, move || build_lm_task(41));
}

/// Periodic schedules on token batches: the off-sync local phases must
/// apply the spec's lr identically in every process (the lr used to be
/// hardcoded at 1e-4 in the local phase — a desync-in-waiting once any
/// run used a different `--lr`), so TCP == loopback still holds with
/// `--lr 1e-3 --sync-every 3`.
#[test]
fn tcp_lm_periodic_schedule_matches_simulated_run() {
    let spec = TrainSpec {
        algo: AlgoSpec::Dad,
        n_sites: 2,
        batch_per_site: 8,
        epochs: 2,
        lr: 1e-3,
        seed: 43,
        schedule: Schedule::Periodic(3),
    };
    check_training_equivalence_with(&spec, move || build_lm_task(43));
}

/// `edad` + the transformer is rejected *before* any frame moves, in both
/// CLI spellings: the `dad train`/`dad serve` argument validation
/// (`validate_dataset_algo`) and the model-aware guard inside the remote
/// training loops that `dad serve`/`dad join` run.
#[test]
fn remote_drivers_reject_edad_for_transformer() {
    // The shared CLI validation (`dad train --dataset lm --algo edad` and
    // `dad serve --dataset lm --algo edad` both route through it).
    let err = validate_dataset_algo("lm", &AlgoSpec::Edad).unwrap_err();
    assert!(err.contains("edad"), "unclear CLI error: {err}");
    assert!(validate_dataset_algo("mnist", &AlgoSpec::Edad).is_ok());

    // Defense in depth: the serve/join loops reject the combination from
    // the model itself, before touching the transport.
    let spec = TrainSpec {
        algo: AlgoSpec::Edad,
        n_sites: 2,
        batch_per_site: 8,
        epochs: 1,
        lr: 1e-3,
        seed: 5,
        schedule: Schedule::EveryBatch,
    };
    let (train_ds, test_ds, shards, model) = build_lm_task(5);
    let mut t = Loopback::new(2);
    let mut ledger = Ledger::new();
    let err = serve_training(
        &mut t,
        &mut ledger,
        &spec,
        model.clone(),
        &train_ds,
        &shards,
        &test_ds,
        FaultPolicy::default(),
    )
    .expect_err("serve must reject edad for the transformer");
    assert!(err.to_string().contains("edad") || err.to_string().contains("architecture"));
    let err = join_training(&mut t, &mut ledger, &spec, model, &train_ds, &shards, 0)
        .expect_err("join must reject edad for the transformer");
    assert!(err.to_string().contains("edad") || err.to_string().contains("architecture"));
}

/// The ISSUE's acceptance criterion at training granularity, for dAD.
#[test]
fn tcp_training_run_matches_simulated_run() {
    check_training_equivalence(&TrainSpec {
        algo: AlgoSpec::Dad,
        n_sites: 2,
        batch_per_site: 8,
        epochs: 2,
        lr: 1e-3,
        seed: 23,
        schedule: Schedule::EveryBatch,
    });
}

/// Full-run equivalence for the compressed algorithm with cross-step
/// adaptive telemetry (rank-dAD): losses, bytes, AUC *and* the per-epoch
/// mean effective ranks must match the simulation.
#[test]
fn tcp_rankdad_training_matches_simulated_run() {
    check_training_equivalence(&TrainSpec {
        algo: AlgoSpec::RankDad { max_rank: 4, n_iters: 6, theta: 1e-3 },
        n_sites: 2,
        batch_per_site: 8,
        epochs: 2,
        lr: 1e-3,
        seed: 29,
        schedule: Schedule::EveryBatch,
    });
}

/// edAD's delta recomputation depends on model weights, which drift per
/// site during periodic local phases — that one combination must be
/// rejected up front (everything else passes), not left to desync
/// silently mid-run.
#[test]
fn remote_validation_rejects_edad_periodic_only() {
    let base = TrainSpec {
        algo: AlgoSpec::Edad,
        n_sites: 2,
        batch_per_site: 8,
        epochs: 1,
        lr: 1e-3,
        seed: 1,
        schedule: Schedule::Periodic(2),
    };
    assert!(validate_remote(&base).is_err(), "edad + periodic must be rejected");
    let edad_every = TrainSpec { schedule: Schedule::EveryBatch, ..base.clone() };
    assert!(validate_remote(&edad_every).is_ok());
    let dad_periodic = TrainSpec { algo: AlgoSpec::Dad, ..base };
    assert!(validate_remote(&dad_periodic).is_ok());
}

/// Periodic schedules with cross-step error-feedback state: the sparse
/// compressors' residuals only advance on sync steps, the off-sync local
/// phases must drift every replica identically, and the site-local DGC
/// momentum/velocity tables must stay in lockstep between the loopback
/// twin and the per-process protocol — TCP == loopback for
/// `--algo dgc:25 --sync-every 2`.
#[test]
fn tcp_sparse_periodic_schedule_matches_simulated_run() {
    check_training_equivalence(&TrainSpec {
        algo: AlgoSpec::Dgc { density: 25.0 },
        n_sites: 2,
        batch_per_site: 8,
        epochs: 2,
        lr: 1e-3,
        seed: 37,
        schedule: Schedule::Periodic(2),
    });
}

/// Periodic sync schedules replay deterministically across processes: the
/// off-sync local phases drift every replica identically, the serving
/// aggregator mirrors site 0 for evaluation, and only every k-th step
/// ships payload bytes.
#[test]
fn tcp_periodic_schedule_matches_simulated_run() {
    let spec = TrainSpec {
        algo: AlgoSpec::Dad,
        n_sites: 2,
        batch_per_site: 8,
        epochs: 2,
        lr: 1e-3,
        seed: 31,
        schedule: Schedule::Periodic(2),
    };
    check_training_equivalence(&spec);
    // Sanity: the periodic run ships strictly fewer bytes than every-batch.
    let (train_ds, test_ds, shards, model) = build_task_200(spec.seed);
    let periodic = train(model.clone(), &spec, &train_ds, &shards, &test_ds);
    let every = train(
        model,
        &TrainSpec { schedule: Schedule::EveryBatch, ..spec.clone() },
        &train_ds,
        &shards,
        &test_ds,
    );
    assert!(periodic.total_bytes() < every.total_bytes());
    assert!(periodic.total_bytes() > 0);
}

// ---------------------------------------------------------------------------
// Chaos: deterministic fault schedules and pure-delay invisibility
// ---------------------------------------------------------------------------

/// Property sweep (in-repo forall idiom): for randomized specs and frame
/// sequences, the fault schedule is a pure function of `(spec, link)` —
/// byte-identical on every evaluation, and divergent whenever the seed or
/// the link changes.
#[test]
fn chaos_fault_schedules_are_byte_identical_per_seed() {
    let mut rng = Rng::new(0x5EED_CAFE);
    for case in 0..32u64 {
        let seed = rng.next_u64();
        let spec = ChaosSpec {
            seed,
            link_cost: Some(CostModel::custom(1e-3, 1e6 + rng.below(1_000_000) as f64)),
            jitter_s: 1e-4 + rng.uniform() as f64 * 0.01,
            drop_every: rng.below(5),
            ..ChaosSpec::default()
        };
        let sizes: Vec<u64> = (0..48).map(|_| 64 + rng.below(1 << 16) as u64).collect();
        // Same seed, same link, same frames: byte-identical — including
        // through an independently reconstructed spec value.
        let twin = spec;
        assert_eq!(
            spec.schedule_bytes(case, &sizes),
            twin.schedule_bytes(case, &sizes),
            "case {case}: same-seed schedules diverged"
        );
        // Seed or link changes re-key the stream: the jittered delays
        // cannot survive 48 frames unchanged.
        let reseeded = ChaosSpec { seed: seed ^ 1, ..spec };
        assert_ne!(
            spec.schedule_bytes(case, &sizes),
            reseeded.schedule_bytes(case, &sizes),
            "case {case}: reseeded schedule did not diverge"
        );
        assert_ne!(
            spec.schedule_bytes(case, &sizes),
            spec.schedule_bytes(case + 1, &sizes),
            "case {case}: link id did not re-key the stream"
        );
    }
}

/// Pure-delay chaos (link cost + jitter, no drops or disconnects) wrapped
/// around the loopback transport must leave the math untouched: grads,
/// losses, telemetry and the per-(tag, direction) ledger exactly equal to
/// the clean simulation, with only `chaos_time_s` recording the injected
/// wire time.
#[test]
fn pure_delay_chaos_is_invisible_on_loopback() {
    let mlp = mk_model(31, &[12, 18, 6]);
    let batches = mk_batches(2, 5, 12, 6, 77);
    let chaos = ChaosSpec::delay_only(7, CostModel::wan_federated(), 0.004);
    assert!(chaos.is_pure_delay() && !chaos.is_quiet());
    for algo in [
        AlgoSpec::Dsgd,
        AlgoSpec::Dad,
        AlgoSpec::RankDad { max_rank: 4, n_iters: 10, theta: 1e-3 },
    ] {
        let name = algo.name();
        let (clean_outs, clean_ledger) = sim_steps(&algo, &mlp, &batches, 2);
        let mut cluster = Cluster::replicate(mlp.clone(), 2)
            .with_transport(Box::new(ChaosTransport::new(Box::new(Loopback::new(2)), chaos, 0)));
        let mut a = algo.build::<Mlp>();
        let outs: Vec<StepOutcome> = (0..2).map(|_| a.step(&mut cluster, &batches)).collect();
        for (s, (clean, delayed)) in clean_outs.iter().zip(&outs).enumerate() {
            assert_eq!(clean.loss, delayed.loss, "{name} step {s}: loss changed under delay");
            for (i, g) in clean.grads.iter().enumerate() {
                assert_eq!(
                    g.max_abs_diff(&delayed.grads[i]),
                    0.0,
                    "{name} step {s}: grad {i} changed under delay"
                );
            }
            assert_eq!(clean.eff_ranks, delayed.eff_ranks, "{name} step {s}: telemetry");
        }
        assert_eq!(
            sorted_rows(&clean_ledger),
            sorted_rows(&cluster.ledger),
            "{name}: ledger breakdown changed under pure delay"
        );
    }
}

/// [`tcp_steps`] with every *site* endpoint wrapped in the same pure-delay
/// [`ChaosSpec`] (accounting mode — the schedule is what matters, not the
/// sleep). Returns per-site results keyed by handshake id plus each site's
/// live fault-event byte log.
fn tcp_steps_delayed<M: DistModel + Clone + Send + 'static>(
    spec: &AlgoSpec,
    model: &M,
    batches: &[Batch],
    steps: usize,
    chaos: ChaosSpec,
) -> (Vec<RemoteStep>, Ledger, Vec<(usize, SiteRun, Vec<u8>)>) {
    let n_sites = batches.len();
    let listener = TcpAgg::bind("127.0.0.1:0", n_sites).expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let handles: Vec<_> = (0..n_sites)
        .map(|_| {
            let addr = addr.clone();
            let model = model.clone();
            let batches = batches.to_vec();
            let spec = spec.clone();
            thread::spawn(move || {
                let site = TcpSite::connect(&addr).expect("connect");
                let site_id = site.site_id();
                let mut t = ChaosTransport::new(Box::new(site), chaos, site_id as u64);
                let mut proto = spec.build::<M>().protocol();
                let mut ledger = Ledger::new();
                let mut ws = Workspace::new();
                let batch = batches[site_id].clone();
                let outs: Vec<RemoteStep> = (0..steps)
                    .map(|_| {
                        remote_site_step(
                            proto.as_mut(),
                            &mut t,
                            &mut ledger,
                            &model,
                            &batch,
                            site_id,
                            &mut ws,
                        )
                        .expect("site step")
                    })
                    .collect();
                assert!(t.chaos_time_s > 0.0, "site {site_id}: no delay was accounted");
                (site_id, (outs, ledger), t.events_bytes())
            })
        })
        .collect();
    let mut agg = listener.accept_sites().expect("accept");
    let mut ledger = Ledger::new();
    let mut proto = spec.build::<M>().protocol();
    let agg_outs: Vec<RemoteStep> = (0..steps)
        .map(|_| {
            remote_agg_step(
                proto.as_mut(),
                &mut agg,
                &mut ledger,
                model,
                None,
                FaultPolicy::default(),
            )
            .expect("agg step")
        })
        .collect();
    let mut sites: Vec<(usize, SiteRun, Vec<u8>)> =
        handles.into_iter().map(|h| h.join().expect("site thread")).collect();
    sites.sort_by_key(|(id, _, _)| *id);
    (agg_outs, ledger, sites)
}

/// The same invisibility guarantee over real TCP sockets, plus schedule
/// determinism at the live-endpoint level: two identical chaos runs
/// produce byte-identical per-site fault-event logs, and both match the
/// clean (chaos-free) run's grads, losses and ledger exactly.
#[test]
fn pure_delay_chaos_is_invisible_and_deterministic_over_tcp() {
    let mlp = mk_model(31, &[12, 18, 6]);
    let batches = mk_batches(2, 5, 12, 6, 77);
    let algo = AlgoSpec::Dad;
    let chaos = ChaosSpec::delay_only(11, CostModel::custom(5e-4, 1e8), 0.002);
    let (clean_agg, clean_ledger, _) = tcp_steps(&algo, &mlp, &batches, 2);
    let (agg_a, ledger_a, sites_a) = tcp_steps_delayed(&algo, &mlp, &batches, 2, chaos);
    let (agg_b, _, sites_b) = tcp_steps_delayed(&algo, &mlp, &batches, 2, chaos);
    for (s, (clean, delayed)) in clean_agg.iter().zip(&agg_a).enumerate() {
        assert_eq!(clean.loss, delayed.loss, "step {s}: loss changed under delay");
        for (i, g) in clean.grads.iter().enumerate() {
            assert_eq!(g.max_abs_diff(&delayed.grads[i]), 0.0, "step {s}: grad {i}");
        }
        assert!(delayed.lost.is_empty(), "pure delay must never retire a site");
    }
    assert_eq!(sorted_rows(&clean_ledger), sorted_rows(&ledger_a), "agg ledger breakdown");
    for ((id_a, (outs_a, l_a), ev_a), (id_b, (outs_b, l_b), ev_b)) in sites_a.iter().zip(&sites_b) {
        assert_eq!(id_a, id_b);
        assert!(!ev_a.is_empty(), "site {id_a}: empty fault-event log");
        assert_eq!(ev_a, ev_b, "site {id_a}: fault schedule not reproducible over TCP");
        assert_eq!(sorted_rows(l_a), sorted_rows(l_b), "site {id_a}: ledger not reproducible");
        for (s, (a, b)) in outs_a.iter().zip(outs_b).enumerate() {
            assert_eq!(a.loss, b.loss, "site {id_a} step {s}: loss not reproducible");
        }
    }
    // Both chaos runs also equal the two per-step losses of the clean
    // site runs by transitivity through the aggregator checks above.
}

// ---------------------------------------------------------------------------
// Tree topologies: hierarchical aggregation and elastic membership
// ---------------------------------------------------------------------------

/// Deterministic dense task with `n_sites` *equal contiguous* shards of
/// `per_site` examples each — equal shards mean every site draws the same
/// step count, so tree runs with different site totals stay
/// step-comparable.
fn build_even_task(
    seed: u64,
    n_sites: usize,
    per_site: usize,
) -> (dad::data::DenseDataset, dad::data::DenseDataset, Vec<Vec<usize>>, Mlp) {
    let n_train = n_sites * per_site;
    let mut rng = Rng::new(seed);
    let full = mnist_like(n_train + 40, &mut rng);
    let train_ds = full.subset(&(0..n_train).collect::<Vec<_>>());
    let test_ds = full.subset(&(n_train..n_train + 40).collect::<Vec<_>>());
    let shards: Vec<Vec<usize>> =
        (0..n_sites).map(|s| (s * per_site..(s + 1) * per_site).collect()).collect();
    (train_ds, test_ds, shards, mk_model(9, &[784, 24, 10]))
}

/// A flat multi-process star at arbitrary site count: serve in this
/// thread, one `join_training` thread per site. Returns the serve log and
/// the aggregator's ledger (the reference the tree runs are held to).
fn flat_training_run<M, D, F>(spec: &TrainSpec, build: F) -> (TrainLog, Ledger)
where
    M: DistModel + Clone + Send + 'static,
    D: DataSource,
    F: Fn() -> (D, D, Vec<Vec<usize>>, M) + Send + Clone + 'static,
{
    let listener = TcpAgg::bind("127.0.0.1:0", spec.n_sites).expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let joins: Vec<_> = (0..spec.n_sites)
        .map(|_| {
            let addr = addr.clone();
            let spec = spec.clone();
            let build = build.clone();
            thread::spawn(move || {
                let mut t = TcpSite::connect(&addr).expect("connect");
                let site_id = t.site_id();
                let (train_ds, _test_ds, shards, model) = build();
                let mut ledger = Ledger::new();
                join_training(&mut t, &mut ledger, &spec, model, &train_ds, &shards, site_id)
                    .expect("join")
            })
        })
        .collect();
    let mut agg = listener.accept_sites().expect("accept");
    let mut ledger = Ledger::new();
    let (train_ds, test_ds, shards, model) = build();
    let log = serve_training(
        &mut agg,
        &mut ledger,
        spec,
        model,
        &train_ds,
        &shards,
        &test_ds,
        FaultPolicy::default(),
    )
    .expect("serve");
    for j in joins {
        j.join().expect("join thread");
    }
    (log, ledger)
}

/// A 2-level aggregation tree over real sockets: the root in this thread,
/// `root_links` relay threads each covering an equal contiguous leaf
/// group, one `join_training` thread per leaf. Returns the root's serve
/// log, the root's own ledger (its reduced uplink view), the union of
/// every leaf's ledger, and the per-leaf logs.
fn tree_training_run<M, D, F>(
    spec: &TrainSpec,
    root_links: usize,
    build: F,
) -> (TrainLog, Ledger, Ledger, Vec<TrainLog>)
where
    M: DistModel + Clone + Send + 'static,
    D: DataSource,
    F: Fn() -> (D, D, Vec<Vec<usize>>, M) + Send + Clone + 'static,
{
    let n_sites = spec.n_sites;
    let listener = TcpAgg::bind("127.0.0.1:0", n_sites).expect("bind root");
    let root_addr = listener.local_addr().expect("addr").to_string();
    let mut site_handles = Vec::new();
    let mut relay_handles = Vec::new();
    for g in 0..root_links {
        let size = n_sites / root_links + usize::from(g < n_sites % root_links);
        let relay_listener = TcpAgg::bind("127.0.0.1:0", size).expect("bind relay");
        let relay_addr = relay_listener.local_addr().expect("relay addr").to_string();
        for _ in 0..size {
            let addr = relay_addr.clone();
            let spec = spec.clone();
            let build = build.clone();
            site_handles.push(thread::spawn(move || {
                let mut t = TcpSite::connect(&addr).expect("connect");
                let site_id = t.site_id();
                let (train_ds, _test_ds, shards, model) = build();
                let mut ledger = Ledger::new();
                let log = join_training(
                    &mut t,
                    &mut ledger,
                    &spec,
                    model,
                    &train_ds,
                    &shards,
                    site_id,
                )
                .expect("join");
                (log, ledger)
            }));
        }
        let parent = root_addr.clone();
        let spec = spec.clone();
        let build = build.clone();
        relay_handles.push(thread::spawn(move || {
            let pending = relay_listener.accept_hellos_deadline(None).expect("relay accept");
            let total = pending.total_leaves();
            let mut up =
                TcpSite::connect_retry_with_leaves(&parent, total, Duration::from_secs(10))
                    .expect("relay dial");
            let leaf_start = up.site_id() as u32;
            let global = up.n_sites() as u32;
            let mut children = pending.welcome_all(leaf_start, global).expect("welcome");
            let (_train_ds, _test_ds, shards, model) = build();
            let cfg = RemoteConfig {
                spec: spec.clone(),
                dataset: String::new(),
                scale: String::new(),
                recv_timeout_ms: 0,
                partition: Partition::Default,
                resume: ResumeMode::Fresh,
            };
            let mut parent_ledger = Ledger::new();
            let mut child_ledger = Ledger::new();
            relay_training(
                &mut up,
                &mut children,
                &mut parent_ledger,
                &mut child_ledger,
                &cfg,
                &shards,
                FaultPolicy::default(),
                model,
            )
            .expect("relay");
        }));
    }
    let mut agg = listener
        .accept_hellos_deadline(None)
        .expect("root accept")
        .welcome_all(0, n_sites as u32)
        .expect("root welcome");
    let mut root_ledger = Ledger::new();
    let (train_ds, test_ds, shards, model) = build();
    let log = serve_training(
        &mut agg,
        &mut root_ledger,
        spec,
        model,
        &train_ds,
        &shards,
        &test_ds,
        FaultPolicy::default(),
    )
    .expect("serve");
    for h in relay_handles {
        h.join().expect("relay thread");
    }
    let mut leaf_union = Ledger::new();
    let mut leaf_logs = Vec::new();
    for h in site_handles {
        let (slog, sledger) = h.join().expect("site thread");
        leaf_union.merge(&sledger);
        leaf_logs.push(slog);
    }
    (log, root_ledger, leaf_union, leaf_logs)
}

/// Per-(tag) rows of one direction, sorted — the unit of the tree ledger
/// equivalence mapping.
fn dir_rows(l: &Ledger, dir: Direction) -> Vec<(String, u64)> {
    let mut rows: Vec<(String, u64)> = l
        .breakdown()
        .iter()
        .filter(|r| r.1 == dir)
        .map(|r| (r.0.clone(), r.2))
        .collect();
    rows.sort();
    rows
}

/// The tentpole acceptance criterion: a 2-level 16-site tree (4 relays x
/// 4 leaves) is bit-equal to the flat star and the loopback simulation —
/// same per-epoch losses and evaluation, and the per-(tag, direction)
/// ledger census maps exactly: the leaves' uplinks sum to the flat star's
/// site->agg rows, the root's broadcast rows equal the flat star's
/// agg->site rows, and the root's *incoming* uplink is never larger than
/// the flat star's (the relays reduce in place).
#[test]
fn tcp_tree_training_matches_flat_star_and_simulation() {
    let algos = [
        AlgoSpec::Dad,
        AlgoSpec::Dsgd,
        AlgoSpec::RankDad { max_rank: 4, n_iters: 6, theta: 1e-3 },
        AlgoSpec::Dgc { density: 25.0 },
    ];
    for algo in algos {
        let spec = TrainSpec {
            algo,
            n_sites: 16,
            batch_per_site: 8,
            epochs: 2,
            lr: 1e-3,
            seed: 47,
            schedule: Schedule::EveryBatch,
        };
        let name = spec.algo.name();
        let build = move || build_even_task(47, 16, 10);
        let (train_ds, test_ds, shards, model) = build();
        let sim_log = train(model, &spec, &train_ds, &shards, &test_ds);
        let (flat_log, flat_ledger) = flat_training_run(&spec, build);
        let (tree_log, root_ledger, leaf_union, leaf_logs) =
            tree_training_run(&spec, 4, build);
        assert_eq!(tree_log.epochs.len(), sim_log.epochs.len(), "{name}: epoch count");
        for (e, (tree, sim)) in tree_log.epochs.iter().zip(&sim_log.epochs).enumerate() {
            assert!(
                (tree.train_loss - sim.train_loss).abs() < 1e-6,
                "{name} epoch {e}: tree loss {} vs sim {}",
                tree.train_loss,
                sim.train_loss
            );
            assert!((tree.test_auc - sim.test_auc).abs() < 1e-5, "{name} epoch {e} AUC");
            assert_eq!(tree.sites_live, 16, "{name} epoch {e}: sites_live");
        }
        for (e, (tree, flat)) in tree_log.epochs.iter().zip(&flat_log.epochs).enumerate() {
            assert!(
                (tree.train_loss - flat.train_loss).abs() < 1e-6,
                "{name} epoch {e}: tree loss {} vs flat {}",
                tree.train_loss,
                flat.train_loss
            );
        }
        // Every leaf sees the same global per-step losses.
        for (leaf, log) in leaf_logs.iter().enumerate() {
            for (e, (srv, site)) in tree_log.epochs.iter().zip(&log.epochs).enumerate() {
                assert!(
                    (srv.train_loss - site.train_loss).abs() < 1e-6,
                    "{name} leaf {leaf} epoch {e} loss"
                );
            }
        }
        // The ledger census mapping (per tag): leaves' uplinks == the flat
        // star's uplink rows; the root's broadcast == the flat star's.
        assert_eq!(
            dir_rows(&leaf_union, Direction::SiteToAgg),
            dir_rows(&flat_ledger, Direction::SiteToAgg),
            "{name}: leaf uplink census"
        );
        assert_eq!(
            dir_rows(&root_ledger, Direction::AggToSite),
            dir_rows(&flat_ledger, Direction::AggToSite),
            "{name}: root broadcast census"
        );
        // The relays reduce: the root's incoming uplink never exceeds the
        // flat star's, per tag.
        for (tag, bytes) in dir_rows(&root_ledger, Direction::SiteToAgg) {
            let flat_bytes = dir_rows(&flat_ledger, Direction::SiteToAgg)
                .into_iter()
                .find(|(t, _)| *t == tag)
                .map(|(_, b)| b)
                .unwrap_or_else(|| panic!("{name}: root shipped unknown tag {tag:?}"));
            assert!(
                bytes <= flat_bytes,
                "{name}: root uplink {tag} grew: tree {bytes} vs flat {flat_bytes}"
            );
        }
    }
}

/// The fan-out law: for a sum-combined protocol (dSGD) the root's
/// incoming uplink bytes are a function of the root's *fan-out*, not the
/// total site count — 16 sites behind 4 relays cost the root exactly what
/// 8 sites behind 4 relays cost, and 4x less than the flat 16-site star.
#[test]
fn tree_root_uplink_bytes_follow_fanout_not_site_count() {
    let spec_n = |n_sites| TrainSpec {
        algo: AlgoSpec::Dsgd,
        n_sites,
        batch_per_site: 16,
        epochs: 1,
        lr: 1e-3,
        seed: 59,
        schedule: Schedule::EveryBatch,
    };
    let (_, root16, _, _) = tree_training_run(&spec_n(16), 4, move || build_even_task(59, 16, 16));
    let (_, root8, _, _) = tree_training_run(&spec_n(8), 4, move || build_even_task(59, 8, 16));
    let (_, flat16) = flat_training_run(&spec_n(16), move || build_even_task(59, 16, 16));
    let up16 = root16.total_dir(Direction::SiteToAgg);
    let up8 = root8.total_dir(Direction::SiteToAgg);
    let up_flat = flat16.total_dir(Direction::SiteToAgg);
    assert!(up16 > 0);
    assert_eq!(up16, up8, "root uplink must depend on fan-out, not site count");
    assert_eq!(up_flat, 4 * up16, "4 relays must cost the root 4/16 of the flat star");
}

/// Fail-fast validation for tree topologies: the non-associative
/// algorithms are rejected by name before any socket opens, and malformed
/// `--topology` spellings are named errors.
#[test]
fn tree_topology_rejects_non_associative_algorithms_end_to_end() {
    let spec = |algo| TrainSpec {
        algo,
        n_sites: 4,
        batch_per_site: 8,
        epochs: 1,
        lr: 1e-3,
        seed: 3,
        schedule: Schedule::EveryBatch,
    };
    for (algo, name) in [(AlgoSpec::Edad, "edad"), (AlgoSpec::DadP2p, "dad-p2p")] {
        let err = validate_remote_topology(&spec(algo.clone()), &Topology::Tree { root_links: 2 })
            .expect_err("non-associative algorithm must be rejected on trees")
            .to_string();
        assert!(err.contains(name), "error must name the algorithm: {err}");
        assert!(err.contains("tree topology"), "error must name the topology: {err}");
        assert!(validate_remote_topology(&spec(algo), &Topology::Flat).is_ok());
    }
    assert_eq!(Topology::parse("flat").unwrap(), Topology::Flat);
    assert_eq!(Topology::parse("tree:4").unwrap(), Topology::Tree { root_links: 4 });
    // Parsing is purely syntactic: `tree:0` parses, validation rejects it.
    assert_eq!(Topology::parse("tree:0").unwrap(), Topology::Tree { root_links: 0 });
    assert!(Topology::parse("tree:x").is_err());
    assert!(Topology::parse("ring").is_err());
    // Fan-out bounds are checked against the spec.
    let dad = spec(AlgoSpec::Dad);
    assert!(validate_remote_topology(&dad, &Topology::Tree { root_links: 0 }).is_err());
    assert!(validate_remote_topology(&dad, &Topology::Tree { root_links: 5 }).is_err());
    assert!(validate_remote_topology(&dad, &Topology::Tree { root_links: 4 }).is_ok());
}

/// Elastic membership over a live flat star: a third site dials a running
/// 2-site fabric, is admitted at the epoch boundary, bootstraps from the
/// `epoch-sync` + `resume` broadcasts, and trains the final epoch as a
/// full member — the run ends with 3 live sites and the joiner's log
/// covering exactly the post-admission epochs.
#[test]
fn elastic_join_admits_a_site_at_the_epoch_boundary() {
    let spec = TrainSpec {
        algo: AlgoSpec::Dad,
        n_sites: 2,
        batch_per_site: 8,
        epochs: 2,
        lr: 1e-3,
        seed: 53,
        schedule: Schedule::EveryBatch,
    };
    let build = move || build_task_200(53);
    let listener = TcpAgg::bind("127.0.0.1:0", 2).expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let incumbents: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            let spec = spec.clone();
            thread::spawn(move || {
                let mut t = TcpSite::connect(&addr).expect("connect");
                let site_id = t.site_id();
                let (train_ds, _test_ds, shards, model) = build();
                let mut ledger = Ledger::new();
                join_training(&mut t, &mut ledger, &spec, model, &train_ds, &shards, site_id)
                    .expect("incumbent")
            })
        })
        .collect();
    let mut agg = listener.accept_sites().expect("accept");
    // The joiner dials *after* the handshake closed: its connection waits
    // in the listener's backlog until the epoch boundary admits it.
    let (dialed_tx, dialed_rx) = std::sync::mpsc::channel::<()>();
    let joiner = {
        let addr = addr.clone();
        thread::spawn(move || {
            dialed_tx.send(()).expect("signal");
            let mut t = TcpSite::connect(&addr).expect("joiner connect");
            let site_id = t.site_id();
            assert_eq!(site_id, 2, "joiner must get the next global leaf id");
            let cfg = RemoteConfig::recv(&mut t).expect("joiner config");
            assert_eq!(cfg.resume, ResumeMode::Elastic, "admission config mode");
            let (train_ds, _test_ds, shards, model) = build();
            let mut ledger = Ledger::new();
            join_training_resumable(
                &mut t,
                &mut ledger,
                &cfg.spec,
                model,
                &train_ds,
                &shards,
                site_id,
                cfg.resume,
            )
            .expect("joiner train")
        })
    };
    dialed_rx.recv().expect("joiner spawned");
    // The SYN lands in the backlog within this margin (loopback); epoch 0
    // takes far longer than the remainder of the dial.
    thread::sleep(Duration::from_millis(100));
    let admit_cfg = RemoteConfig {
        spec: spec.clone(),
        dataset: "mnist".into(),
        scale: "quick".into(),
        recv_timeout_ms: 0,
        partition: Partition::Default,
        resume: ResumeMode::Fresh,
    };
    let plan = CheckpointPlan {
        save_path: None,
        every: 0,
        dataset: "mnist".into(),
        scale: "quick".into(),
    };
    let mut ledger = Ledger::new();
    let (train_ds, test_ds, shards, model) = build();
    let serve_log = serve_training_checkpointed(
        &mut agg,
        &mut ledger,
        &spec,
        model,
        &train_ds,
        &shards,
        &test_ds,
        FaultPolicy::default(),
        &plan,
        None,
        Some(&admit_cfg),
    )
    .expect("serve");
    assert_eq!(serve_log.epochs.len(), 2);
    assert_eq!(serve_log.epochs[0].sites_live, 2, "epoch 0 runs with the incumbents");
    assert_eq!(serve_log.epochs[1].sites_live, 3, "epoch 1 runs with the admitted joiner");
    for h in incumbents {
        let log = h.join().expect("incumbent thread");
        assert_eq!(log.epochs.len(), 2);
    }
    let joiner_log = joiner.join().expect("joiner thread");
    assert_eq!(joiner_log.epochs.len(), 1, "joiner trains only the post-admission epoch");
    assert_eq!(joiner_log.epochs[0].epoch, 1);
    assert!(joiner_log.epochs[0].train_loss.is_finite());
}
